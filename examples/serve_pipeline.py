"""End-to-end serving driver: REAL JAX models behind every pipeline stage,
batched requests flowing through the stage chain, and the OPD agent
reconfiguring the live system (variant switch / batch size / replicas)
while it serves.

    PYTHONPATH=src python examples/serve_pipeline.py [--requests 96] [--train-episodes 4]

This is the paper's Fig.1 system: Batcher = per-stage centralized queue,
PipelineServer = gRPC stage chain, apply_config = the Kubernetes-API
reconfiguration. Models are smoke-scale instances of the assigned
architectures so the driver runs on CPU in minutes.
"""
import argparse
import time

import numpy as np

from repro.cluster import PipelineEnv, make_trace
from repro.cluster.perf_model import make_pipeline
from repro.configs import ARCHS
from repro.core import OPDPolicy, OPDTrainer, PPOConfig
from repro.data.tokens import synthetic_requests
from repro.serving.batcher import Request
from repro.serving.engine import PipelineServer, StageServer

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=96)
ap.add_argument("--train-episodes", type=int, default=4)
ap.add_argument("--seq-len", type=int, default=32)
args = ap.parse_args()

# --- the data plane: 3 stages, each with two smoke-scale variant models ----
stage_archs = [
    [ARCHS["xlstm-125m"].smoke(), ARCHS["whisper-small"].smoke()],
    [ARCHS["llama3.2-1b"].smoke(), ARCHS["starcoder2-3b"].smoke()],
    [ARCHS["granite-moe-3b-a800m"].smoke(), ARCHS["zamba2-2.7b"].smoke()],
]
t0 = time.time()
stages = [StageServer(f"stage{i}", variants, seq_len=args.seq_len,
                      batch_size=4, seed=i)
          for i, variants in enumerate(stage_archs)]
server = PipelineServer(stages)
print(f"built 3-stage pipeline with {sum(len(s) for s in stage_archs)} live "
      f"JAX models in {time.time() - t0:.1f}s")

# --- the control plane: OPD agent trained on the matching simulator --------
pipe = make_pipeline([[ARCHS[n] for n in ("xlstm-125m", "whisper-small")],
                      [ARCHS[n] for n in ("llama3.2-1b", "starcoder2-3b")],
                      [ARCHS[n] for n in ("granite-moe-3b-a800m", "zamba2-2.7b")]],
                     name="serve3", quants=("bf16",))


def make_env(seed):
    return PipelineEnv(pipe, make_trace("fluctuating", seed=seed), seed=seed)


trainer = OPDTrainer(pipe, make_env, ppo=PPOConfig(expert_freq=2), seed=0)
for ep in range(1, args.train_episodes + 1):
    trainer.train_episode(ep, env_seed=ep)
agent = OPDPolicy(pipe, trainer.params)
env = make_env(123)
env.reset()

# --- serve: requests arrive in waves; agent reconfigures between waves -----
reqs = synthetic_requests(args.requests, seq_len=args.seq_len)
waves = np.array_split(np.asarray(reqs, dtype=object), 4)
served_total = 0
for w, wave in enumerate(waves):
    cfg = agent(env)                       # control decision (measured)
    server.apply_config(cfg)
    env.step(cfg)                          # advance the simulated cell
    t0 = time.time()
    for req in wave:
        server.submit(req)
    done = server.process()
    dt = time.time() - t0
    served_total = len(done)
    print(f"wave {w}: cfg z={cfg.z} f={cfg.f} b={cfg.b} -> "
          f"{len(wave)} reqs in {dt:.2f}s "
          f"({len(wave) / max(dt, 1e-9):.1f} req/s), "
          f"decision {agent.decision_times[-1] * 1e3:.1f} ms")

print(f"served {served_total}/{args.requests} requests end-to-end; "
      f"{server.switch_count} live variant switches")
assert served_total == args.requests, "every request must complete"
