"""Closed-loop serving demo: the event-driven runtime serves a bursty
arrival trace through a multi-stage pipeline while the OPD agent
reconfigures the live system (variant switch / replicas / batch size) every
adaptation interval.

    PYTHONPATH=src python examples/serve_pipeline.py \
        [--horizon 120] [--train-episodes 4] [--scenario bursty] [--real]

The agent trains on the analytic simulator (PipelineEnv), then controls the
real thing: RuntimeEnv steps the virtual-time event loop one 10 s interval
per decision — continuous batchers (timeout-or-full), per-batch service
times from the perf model, variant switches paying cold start in virtual
time. ``--real`` additionally attaches smoke-scale JAX models as stage
executors so request tokens flow through live forward passes.
"""
import argparse
import time

import numpy as np

from repro.cluster import PipelineEnv, RuntimeEnv
from repro.cluster.perf_model import make_pipeline
from repro.configs import ARCHS
from repro.core import OPDPolicy, OPDTrainer, PPOConfig
from repro.serving import SCENARIOS, make_arrivals
from repro.serving.engine import StageServer

ap = argparse.ArgumentParser()
ap.add_argument("--horizon", type=int, default=120,
                help="virtual seconds of traffic to serve")
ap.add_argument("--train-episodes", type=int, default=4)
ap.add_argument("--scenario", default="bursty", choices=SCENARIOS)
ap.add_argument("--seq-len", type=int, default=32)
ap.add_argument("--real", action="store_true",
                help="attach live smoke-scale JAX models as stage executors")
args = ap.parse_args()

STAGE_ARCHS = [("xlstm-125m", "whisper-small"),
               ("llama3.2-1b", "starcoder2-3b"),
               ("granite-moe-3b-a800m", "zamba2-2.7b")]

pipe = make_pipeline([[ARCHS[n] for n in names] for names in STAGE_ARCHS],
                     name="serve3", quants=("bf16",))

arrivals = make_arrivals(args.scenario, rate=25.0, seed=7)

# --- control plane: OPD agent trained on the matching analytic simulator ---
# (trained against the scenario's own rate profile so the expert-guided
# episodes cover the demand levels the runtime will actually see)
train_trace = arrivals.rates(1200)

def make_env(seed):
    return PipelineEnv(pipe, np.roll(train_trace, 37 * seed), seed=seed)

t0 = time.time()
trainer = OPDTrainer(pipe, make_env, ppo=PPOConfig(expert_freq=2), seed=0)
for ep in range(1, args.train_episodes + 1):
    trainer.train_episode(ep, env_seed=ep)
agent = OPDPolicy(pipe, trainer.params)
print(f"trained OPD agent for {args.train_episodes} episodes "
      f"in {time.time() - t0:.1f}s")

# --- data plane: the event-driven runtime -----------------------------------
executors = None
if args.real:
    t0 = time.time()
    servers = [StageServer(f"stage{i}", [ARCHS[n].smoke() for n in names],
                           seq_len=args.seq_len, seed=i)
               for i, names in enumerate(STAGE_ARCHS)]
    executors = [s.execute for s in servers]
    print(f"built {sum(len(n) for n in STAGE_ARCHS)} live JAX models "
          f"in {time.time() - t0:.1f}s")

env = RuntimeEnv(pipe, arrivals, horizon=args.horizon,
                 executors=executors, seq_len=args.seq_len)
print(f"loaded {env.submitted} requests over {args.horizon}s "
      f"({args.scenario} arrivals); serving with OPD in the loop\n")

done = False
costs = []
wall0 = time.time()
while not done:
    cfg = agent(env)                       # control decision (measured, wall)
    _, r, done, info = env.step(cfg)       # 10 s of virtual serving
    costs.append(info["cost"])
    p95 = info["p95"]
    print(f"[t={env.runtime.now:5.0f}s] z={cfg.z} f={cfg.f} b={cfg.b} "
          f"demand={info['demand']:5.1f}/s served={info['processed']:4d} "
          f"p50={info['p50'] * 1e3:6.1f}ms p95={p95 * 1e3:6.1f}ms "
          f"p99={info['p99'] * 1e3:6.1f}ms backlog={info['backlog']:4d} "
          f"cost={info['cost']:4.0f} "
          f"decision={agent.decision_times[-1] * 1e3:5.1f}ms"
          + (" [switch]" if info["switched"] else ""))

summary = env.drain()                      # finish in-flight work
wall = time.time() - wall0
rt = env.runtime
print(f"\nserved {summary['served']}/{env.submitted} requests "
      f"({summary['throughput_rps']:.1f} req/s virtual, "
      f"{summary['served'] / max(wall, 1e-9):.0f} req/s wall)")
print(f"latency p50={summary['p50'] * 1e3:.1f}ms "
      f"p95={summary['p95'] * 1e3:.1f}ms p99={summary['p99'] * 1e3:.1f}ms "
      f"mean={summary['latency_mean_s'] * 1e3:.1f}ms")
print(f"mean cost={np.mean(costs):.1f} chips, "
      f"{rt.switch_count} live variant switches, "
      f"mean batch={summary['mean_batch_size']:.1f}, "
      f"decision H={sum(agent.decision_times):.3f}s over "
      f"{len(agent.decision_times)} decisions")
print(f"stage utilization: "
      + " ".join(f"{u:.2f}" for u in rt.utilization()))
assert summary["served"] == env.submitted, "every request must complete"
