"""Closed-loop serving demo: the event-driven runtime serves a bursty
arrival trace through a multi-stage pipeline while the OPD agent
reconfigures the live system (variant switch / replicas / batch size) every
adaptation interval.

    PYTHONPATH=src python examples/serve_pipeline.py \
        [--horizon 120] [--train-episodes 4] [--scenario bursty] [--real]

Everything is declared through ``repro.api``: the registered "serve3"
pipeline, an arrival ScenarioSpec, and an OPD ControllerSpec. The Session
trains the agent on the analytic simulator (PipelineEnv) over the scenario's
own rate profile, then controls the real thing: RuntimeEnv steps the
virtual-time event loop one 10 s interval per decision. ``--real``
additionally attaches smoke-scale JAX models as stage executors so request
tokens flow through live forward passes.
"""
import argparse
import time

import numpy as np

from repro import api

ap = argparse.ArgumentParser()
ap.add_argument("--horizon", type=int, default=120,
                help="virtual seconds of traffic to serve")
ap.add_argument("--train-episodes", type=int, default=4)
ap.add_argument("--scenario", default="bursty", choices=api.list_scenarios())
ap.add_argument("--seq-len", type=int, default=32)
ap.add_argument("--real", action="store_true",
                help="attach live smoke-scale JAX models as stage executors")
args = ap.parse_args()

exp = api.ExperimentSpec(
    pipeline=api.get_pipeline("serve3"),
    scenario=api.replace(api.get_scenario(args.scenario), rate=25.0, seed=7,
                         horizon=args.horizon),
    controller=api.replace(api.get_controller("opd"),
                           train_episodes=args.train_episodes, expert_freq=2),
    real=args.real, seq_len=args.seq_len)
sess = api.Session.from_spec(exp)

# --- control plane: OPD agent trained on the matching analytic simulator ---
t0 = time.time()
sess.train()
print(f"trained OPD agent for {args.train_episodes} episodes "
      f"in {time.time() - t0:.1f}s")

# --- data plane: the event-driven runtime -----------------------------------
agent = sess.controller = sess.build_controller()


def show(env, cfg, info):
    print(f"[t={env.runtime.now:5.0f}s] z={cfg.z} f={cfg.f} b={cfg.b} "
          f"demand={info['demand']:5.1f}/s served={info['processed']:4d} "
          f"p50={info['p50'] * 1e3:6.1f}ms p95={info['p95'] * 1e3:6.1f}ms "
          f"p99={info['p99'] * 1e3:6.1f}ms backlog={info['backlog']:4d} "
          f"cost={info['cost']:4.0f} "
          f"decision={agent.decision_times[-1] * 1e3:5.1f}ms"
          + (" [switch]" if info["switched"] else ""))


wall0 = time.time()
report = sess.serve(on_step=show)
wall = time.time() - wall0

summary = report["summary"]
submitted = summary["submitted"]


def ms(v):
    # summary latency fields are None (not NaN) when nothing completed
    return "n/a" if v is None else f"{v * 1e3:.1f}ms"


print(f"\nserved {summary['served']}/{submitted} requests "
      f"({summary['throughput_rps']:.1f} req/s virtual, "
      f"{summary['served'] / max(wall, 1e-9):.0f} req/s wall)")
print(f"latency p50={ms(summary['p50'])} "
      f"p95={ms(summary['p95'])} p99={ms(summary['p99'])} "
      f"mean={ms(summary['latency_mean_s'])}")
print(f"mean cost={np.mean(report['cost']):.1f} chips, "
      f"{summary['switches']} live variant switches, "
      f"mean batch={summary['mean_batch_size']:.1f}, "
      f"decision H={report['decision_time_total']:.3f}s over "
      f"{len(report['decision_times'])} decisions")
print("stage utilization: "
      + " ".join(f"{u:.2f}" for u in summary["utilization"]))
assert summary["served"] == submitted, "every request must complete"
