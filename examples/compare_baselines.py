"""Compare Random / Greedy / IPA / OPD on one workload cycle (paper Fig. 4-5
in miniature).

    PYTHONPATH=src python examples/compare_baselines.py [--workload fluctuating]
"""
import argparse

from repro.cluster import PipelineEnv, default_pipeline, make_trace
from repro.core import (GreedyPolicy, IPAPolicy, OPDPolicy, OPDTrainer,
                        PPOConfig, RandomPolicy, run_episode)

ap = argparse.ArgumentParser()
ap.add_argument("--workload", default="fluctuating",
                choices=["steady_low", "fluctuating", "steady_high"])
ap.add_argument("--episodes", type=int, default=8)
args = ap.parse_args()

pipe = default_pipeline()


def make_env(seed):
    return PipelineEnv(pipe, make_trace(args.workload, seed=seed), seed=seed)


trainer = OPDTrainer(pipe, make_env, ppo=PPOConfig(expert_freq=3), seed=0)
for ep in range(1, args.episodes + 1):
    trainer.train_episode(ep, env_seed=ep)

print(f"\n{args.workload}: 1200 s cycle, 10 s adaptation interval")
print(f"{'policy':8s} {'cost(chips)':>12s} {'QoS':>9s} {'latency(s)':>11s} "
      f"{'decision H(s)':>14s}")
for name, pol in (("random", RandomPolicy(pipe, seed=7)),
                  ("greedy", GreedyPolicy(pipe)),
                  ("ipa", IPAPolicy(pipe)),
                  ("opd", OPDPolicy(pipe, trainer.params))):
    res = run_episode(make_env(42), pol)
    h = res.get("decision_time_total", float("nan"))
    print(f"{name:8s} {res['cost'].mean():12.2f} {res['qos'].mean():9.2f} "
          f"{res['latency'].mean():11.3f} {h:14.3f}")
