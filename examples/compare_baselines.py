"""Compare Random / Greedy / IPA / OPD on one workload cycle (paper Fig. 4-5
in miniature), built entirely from ``repro.api`` specs.

    PYTHONPATH=src python examples/compare_baselines.py [--workload fluctuating]
"""
import argparse

from repro import api

ap = argparse.ArgumentParser()
ap.add_argument("--workload", default="fluctuating",
                choices=["steady_low", "fluctuating", "steady_high"])
ap.add_argument("--episodes", type=int, default=8)
args = ap.parse_args()

scenario = api.replace(api.get_scenario(args.workload), seed=42)

print(f"\n{args.workload}: {scenario.horizon} s cycle, 10 s adaptation interval")
print(f"{'policy':8s} {'cost(chips)':>12s} {'QoS':>9s} {'latency(s)':>11s} "
      f"{'decision H(s)':>14s}")
for name in ("random", "greedy", "ipa", "opd"):
    controller = api.replace(api.get_controller(name), seed=7,
                             train_episodes=args.episodes, expert_freq=3)
    exp = api.ExperimentSpec(pipeline=api.get_pipeline("paper-4stage"),
                             scenario=scenario, controller=controller,
                             backend="analytic")
    res = api.run_experiment(exp)
    cost = sum(res["cost"]) / len(res["cost"])
    qos = sum(res["qos"]) / len(res["qos"])
    lat = sum(res["latency"]) / len(res["latency"])
    h = res.get("decision_time_total", float("nan"))
    print(f"{name:8s} {cost:12.2f} {qos:9.2f} {lat:11.3f} {h:14.3f}")
