"""End-to-end LM training driver: train a ~100M-parameter model for a few
hundred steps on the synthetic Markov-automaton corpus and watch the loss
fall well below log(V).

    PYTHONPATH=src python examples/train_lm.py [--arch xlstm-125m] [--steps 300]

Uses the very same make_train_step / sharding code path the multi-pod
dry-run compiles for the 512-chip mesh — here on the local device(s).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.data.tokens import synthetic_lm_batches
from repro.models import api, steps
from repro.train import adamw_init

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="xlstm-125m", choices=sorted(ARCHS))
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--seq-len", type=int, default=256)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--d-model", type=int, default=None,
                help="override width (default: ~100M-param reduction)")
args = ap.parse_args()

base = ARCHS[args.arch]
# reduce to ~100M params for a CPU-trainable run, keep the family intact
cfg = base.replace(n_layers=min(base.n_layers, 8),
                   d_model=args.d_model or min(base.d_model, 512),
                   n_heads=min(base.n_heads, 8),
                   n_kv=min(base.n_kv, 8),
                   d_ff=min(base.d_ff, 2048) if base.d_ff else 0,
                   n_experts=min(base.n_experts, 4) if base.n_experts else 0,
                   vocab=min(base.vocab, 32768))
print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params "
      f"({cfg.active_param_count() / 1e6:.1f}M active), vocab={cfg.vocab}")

params = api.init_model(jax.random.PRNGKey(0), cfg)
opt = adamw_init(params)
train = jax.jit(steps.make_train_step(cfg, lr=1e-3))
data = synthetic_lm_batches(vocab=cfg.vocab, seq_len=args.seq_len,
                            batch=args.batch, seed=0)

log_v = float(np.log(cfg.vocab))
print(f"uniform-token floor: log(V) = {log_v:.3f}")
t0 = time.time()
first = None
for step in range(1, args.steps + 1):
    batch = {k: jax.numpy.asarray(v) for k, v in next(data).items()}
    params, opt, metrics = train(params, opt, batch)
    if step == 1:
        first = float(metrics["loss"])
    if step % 20 == 0 or step == 1:
        print(f"step {step:4d}  loss={float(metrics['loss']):7.4f}  "
              f"grad_norm={float(metrics['grad_norm']):8.3f}  "
              f"{(time.time() - t0) / step:5.2f}s/step")

final = float(metrics["loss"])
print(f"\nloss {first:.3f} -> {final:.3f} "
      f"({'below' if final < log_v else 'NOT below'} log V = {log_v:.3f})")
assert final < first, "loss must decrease"
