"""Quickstart — the paper's pipeline + OPD agent in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the 4-stage edge pipeline (stages backed by the assigned
architectures), trains the OPD agent for a handful of PPO episodes with
expert guidance, then evaluates it against the Greedy baseline on a
fluctuating workload cycle.
"""
import numpy as np

from repro.cluster import PipelineEnv, default_pipeline, make_trace
from repro.core import (GreedyPolicy, OPDPolicy, OPDTrainer, PPOConfig,
                        run_episode)

pipe = default_pipeline()
print(f"pipeline: {pipe.name}, {len(pipe.tasks)} stages, "
      f"{sum(len(t.variants) for t in pipe.tasks)} model variants total")


def make_env(seed):
    return PipelineEnv(pipe, make_trace("fluctuating", seed=seed), seed=seed)


trainer = OPDTrainer(pipe, make_env, ppo=PPOConfig(expert_freq=3), seed=0)
for ep in range(1, 9):
    trainer.train_episode(ep, env_seed=ep)
    print(f"episode {ep}: reward={trainer.history['reward'][-1]:9.2f} "
          f"loss={trainer.history['loss'][-1]:7.3f} "
          f"expert={trainer.history['expert'][-1]}")

for name, policy in (("greedy", GreedyPolicy(pipe)),
                     ("opd", OPDPolicy(pipe, trainer.params))):
    res = run_episode(make_env(99), policy)
    print(f"{name:6s}: mean cost={res['cost'].mean():7.2f} chips  "
          f"mean QoS={res['qos'].mean():7.2f}  "
          f"unmet demand={np.clip(res['excess'], 0, None).mean():6.3f} req/s")
