"""Quickstart — the paper's pipeline + OPD agent through the declarative
control-plane API, in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py [--episodes 8]

Builds the registered 4-stage edge pipeline, trains the OPD agent for a
handful of PPO episodes with expert guidance, then evaluates it against the
Greedy baseline on a fluctuating workload cycle. The whole experiment is an
``ExperimentSpec`` — serialize it with ``json.dumps(exp.to_dict())`` and any
machine reproduces this run bit-for-bit.
"""
import argparse

import numpy as np

from repro import api

ap = argparse.ArgumentParser()
ap.add_argument("--episodes", type=int, default=8)
args = ap.parse_args()

pipe_spec = api.get_pipeline("paper-4stage")
pipe = pipe_spec.build()
print(f"pipeline: {pipe.name}, {len(pipe.tasks)} stages, "
      f"{sum(len(t.variants) for t in pipe.tasks)} model variants total")

scenario = api.replace(api.get_scenario("fluctuating"), seed=99)
for name in ("greedy", "opd"):
    exp = api.ExperimentSpec(
        pipeline=pipe_spec, scenario=scenario, backend="analytic",
        controller=api.replace(api.get_controller(name),
                               train_episodes=args.episodes, expert_freq=3))
    res = api.run_experiment(exp, log=print)
    excess = np.clip(res["excess"], 0, None)
    print(f"{name:6s}: mean cost={np.mean(res['cost']):7.2f} chips  "
          f"mean QoS={np.mean(res['qos']):7.2f}  "
          f"unmet demand={excess.mean():6.3f} req/s")
