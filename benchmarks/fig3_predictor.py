"""Fig. 3 — LSTM workload predictor accuracy (paper: SMAPE ~6%).

Trains the 25-unit LSTM + dense(1) predictor on held-out seeds per workload
regime and reports SMAPE on an unseen seed; plus prediction latency (paper:
"trained to predict workloads in under 50 milliseconds").
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import save_results
from repro.cluster import make_trace
from repro.core.predictor import predict_batch, smape, train_predictor

SCALE = 120.0


def run(quick: bool = False):
    rows, payload = [], {}
    epochs = 4 if quick else 12
    for kind in ("steady_low", "fluctuating", "steady_high"):
        traces = [make_trace(kind, seed=s) for s in range(2 if quick else 4)]
        params = train_predictor(traces, scale=SCALE, epochs=epochs, seed=0, log=None)
        err = smape(params, [make_trace(kind, seed=9)], scale=SCALE)
        payload[kind] = {"smape_pct": err}
        rows.append(("fig3", f"smape_{kind}_pct", round(err, 2), "paper ~6%"))

    # decision latency of one prediction (paper: < 50 ms)
    hist = jnp.asarray(make_trace("fluctuating", seed=3)[:120], dtype=jnp.float32)[
        None
    ] / SCALE
    predict_batch(params, hist).block_until_ready()   # warm
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        predict_batch(params, hist).block_until_ready()
    ms = (time.perf_counter() - t0) / reps * 1e3
    payload["predict_latency_ms"] = ms
    rows.append(("fig3", "predict_latency_ms", round(ms, 2), "paper <50ms"))
    save_results("fig3_predictor", payload)
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
