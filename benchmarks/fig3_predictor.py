"""Fig. 3 — learned load prediction accuracy (paper: SMAPE ~6%).

Two sections:

1. The paper-faithful §IV-A predictor: per workload regime, train the
   25-unit LSTM + dense(1) on held-out seeds, report SMAPE on an unseen
   seed and the per-regime single-prediction latency (paper: "trained to
   predict workloads in under 50 milliseconds") — each regime's *own*
   params, timed with the shared min-of-k harness (``repro.timing``).
2. The multi-horizon forecaster (``core/forecast.py``): both backbones
   (lstm / mlstm) trained on the fluctuating regime, SMAPE and q90
   pinball loss per horizon {5, 10, 20, 60} s on an unseen seed, plus
   single-window latency and batch predictions/s (the CI gate metrics).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_results, time_fn
from repro.cluster import make_trace
from repro.core import forecast
from repro.core.predictor import predict_batch, smape, train_predictor

SCALE = 120.0
BACKBONES = ("lstm", "mlstm")


def run(quick: bool = False):
    rows, payload = [], {}
    epochs = 4 if quick else 12
    for kind in ("steady_low", "fluctuating", "steady_high"):
        traces = [make_trace(kind, seed=s) for s in range(2 if quick else 4)]
        params = train_predictor(traces, scale=SCALE, epochs=epochs, seed=0,
                                 log=None)
        err = smape(params, [make_trace(kind, seed=9)], scale=SCALE)

        # per-regime single-prediction latency on this regime's own params
        # (paper: < 50 ms) — min-of-k with device sync inside the clock
        hist = jnp.asarray(make_trace(kind, seed=3)[:120],
                           dtype=jnp.float32)[None] / SCALE
        t = time_fn(lambda p=params, h=hist: predict_batch(p, h),
                    reps=20, warmup=2)
        ms = t.best * 1e3
        payload[kind] = {"smape_pct": err, "predict_latency_ms": ms}
        rows.append(("fig3", f"smape_{kind}_pct", round(err, 2), "paper ~6%"))
        rows.append(("fig3", f"predict_latency_{kind}_ms", round(ms, 2),
                     "paper <50ms"))

    payload["forecast"] = {}
    fc_epochs = {"lstm": 3 if quick else 8, "mlstm": 5 if quick else 20}
    fc_lr = {"lstm": 5e-3, "mlstm": 3e-3}
    traces = [make_trace("fluctuating", seed=s)
              for s in range(2 if quick else 4)]
    eval_traces = [make_trace("fluctuating", seed=9)]
    for backbone in BACKBONES:
        params, ch = forecast.train_forecaster(
            traces, backbone=backbone, scale=SCALE,
            epochs=fc_epochs[backbone], lr=fc_lr[backbone], seed=0)
        sm = forecast.smape_horizons(params, eval_traces, backbone=backbone,
                                     scale=SCALE, channel_scales=ch)
        pb = forecast.pinball_horizons(params, eval_traces, backbone=backbone,
                                       scale=SCALE, channel_scales=ch)
        X, _, _ = forecast.make_forecast_dataset(eval_traces, scale=SCALE,
                                                 channel_scales=ch)
        Xj = jnp.asarray(X)
        one = Xj[:1]
        t1 = time_fn(lambda p=params, h=one, b=backbone:
                     forecast.forecast_batch(p, h, backbone=b),
                     reps=20, warmup=2)
        tb = time_fn(lambda p=params, h=Xj, b=backbone:
                     forecast.forecast_batch(p, h, backbone=b),
                     reps=5, warmup=1)
        per_s = len(X) / tb.best
        payload["forecast"][backbone] = {
            "smape_pct": {str(h): v for h, v in sm.items()},
            "smape_mean_pct": float(np.mean(list(sm.values()))),
            "pinball_q90": {str(h): v for h, v in pb.items()},
            "predict_latency_ms": t1.best * 1e3,
            "predictions_per_s": per_s,
        }
        for h, v in sm.items():
            rows.append(("fig3", f"forecast_{backbone}_smape_{h}s_pct",
                         round(v, 2), "paper ~6% @20s"))
        rows.append(("fig3", f"forecast_{backbone}_predictions_per_s",
                     round(per_s, 0), ""))
    save_results("fig3_predictor", payload)
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
