"""Stage-calibration benchmark: measured vs predicted stage latency.

Runs the ``StageExecutor`` grid — model-zoo archs × batch sizes × quants on
a CPU (or accelerator) device mesh — and reports, per variant:

  * the measured min-of-k ``latency(b)`` curve (AOT-compiled, sharded,
    Pallas-backed when ``backend="flash"``);
  * the least-squares ``(alpha, beta)`` fit and its mean relative error
    (``fit_mre_mean`` — how linear the real curve is; bench-smoke gates it
    with ``--max-ratio``);
  * the analytic ``perf_model`` prediction and its MRE against the
    measurement (``analytic_mre_mean`` — the honest sim-to-real gap; the
    analytic model describes TPU v5e, the CI mesh is host CPU, so this is
    reported, not gated);
  * the HLO roofline (``launch/hlo_cost.py`` flops/bytes against the
    perf-model's peak constants) next to the measured time.

The whole grid then repeats against the shared ``ExecutableCache`` —
``cache.hit_rate_repeat`` must stay ~1.0 (gated with ``--min-ratio``):
repeated configurations never recompile. A second 1-device executor probes
the same stage to turn mesh-width speedup into measured device-class speed
factors. The emitted payload embeds the fitted ``CalibrationTable`` under
``"table"``, so the committed baseline in experiments/results/ doubles as
the artifact ``PipelineSpec(perf_source="calibrated")`` loads by default.
"""
from __future__ import annotations

import os
import platform
import sys

# a multi-device host mesh only exists if XLA is told so before jax loads
if "jax" not in sys.modules and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import save_results  # noqa: E402
from repro import compat  # noqa: E402
from repro.cluster.calibration import (CalibrationTable,  # noqa: E402
                                       fit_alpha_beta, mean_relative_error,
                                       predict)
from repro.cluster.executor import ExecutableCache, StageExecutor  # noqa: E402
from repro.cluster.perf_model import (EFFICIENCY, HBM_BW,  # noqa: E402
                                      PEAK_FLOPS, variant_from_arch)

QUICK_ARCHS = ("llama3.2-1b", "whisper-small")
FULL_ARCHS = QUICK_ARCHS + ("xlstm-125m", "starcoder2-3b")
SPEED_PROBE = ("llama3.2-1b", 2)      # (arch, batch) timed on both meshes


def roofline_s(flops: float, bytes_: float) -> float:
    """Analytic lower bound for one step from its HLO counts, against the
    perf-model's peak constants (meaningful on the accelerator those
    constants describe; reported for trend on CPU)."""
    return max(flops / (PEAK_FLOPS * EFFICIENCY), bytes_ / HBM_BW)


def run(quick: bool = False):
    archs = QUICK_ARCHS if quick else FULL_ARCHS
    # start at b=2: XLA's CPU batch-1 decode hits a degenerate single-row
    # GEMV path ~5x off the batch-linear trend, which would dominate the fit
    batches = (2, 4, 8) if quick else (2, 4, 8, 16)
    quants = ("bf16",) if quick else ("bf16", "int8")
    reps = 3 if quick else 5

    cache = ExecutableCache()
    ex = StageExecutor(cache=cache)           # all local devices, model axis
    grid = [(a, b, q, "reference") for a in archs for q in quants
            for b in batches]
    if not quick:
        # Pallas backend on the attention-heavy stage (interpret mode on CPU)
        grid += [("llama3.2-1b", b, "bf16", "flash") for b in batches]

    # ---- measurement pass (every configuration is a compile miss) -------
    timings = [ex.measure(a, b, q, bk, reps=reps) for a, b, q, bk in grid]

    # ---- per-variant fits and predicted-vs-measured errors --------------
    variants: dict[str, dict] = {}
    fit_timings = []                          # reference backend -> table
    for t in timings:
        key = f"{t.arch}:{t.quant}" + ("" if t.backend == "reference"
                                       else f"@{t.backend}")
        v = variants.setdefault(key, {"batches": [], "measured_s": [],
                                      "flops": t.flops, "bytes": t.bytes,
                                      "compile_s_first": t.compile_s})
        v["batches"].append(t.batch)
        v["measured_s"].append(t.latency_s)
        if t.backend == "reference":
            fit_timings.append(t)
    for name, v in variants.items():
        alpha, beta = fit_alpha_beta(v["batches"], v["measured_s"])
        fitted = predict(alpha, beta, v["batches"])
        v["fitted"] = [alpha, beta]
        v["fit_mre"] = mean_relative_error(fitted, v["measured_s"])
        arch, quant = name.split("@")[0].rsplit(":", 1)
        av = variant_from_arch(ex.arch_config(arch), quant=quant)
        v["analytic"] = [av.alpha, av.beta]
        v["analytic_mre"] = mean_relative_error(
            predict(av.alpha, av.beta, v["batches"]), v["measured_s"])
        v["roofline_s"] = roofline_s(v["flops"], v["bytes"])

    fit_mre_mean = float(np.mean([v["fit_mre"] for v in variants.values()]))
    analytic_mre_mean = float(np.mean([v["analytic_mre"]
                                       for v in variants.values()]))

    # ---- repeat pass: the executable cache must absorb every lookup -----
    hits0, lookups0 = cache.hits, cache.lookups
    for a, b, q, bk in grid:
        ex.measure(a, b, q, bk, reps=1, warmup=0)
    repeat_lookups = cache.lookups - lookups0
    hit_rate_repeat = (cache.hits - hits0) / repeat_lookups

    # ---- device-class speed factors: 1-device probe vs the full mesh ----
    arch_p, batch_p = SPEED_PROBE
    ex1 = StageExecutor(compat.make_mesh((1, 1), ("data", "model")),
                        cache=cache)
    t1 = ex1.measure(arch_p, batch_p, reps=reps)
    tn = next(t for t in timings
              if (t.arch, t.batch, t.quant, t.backend)
              == (arch_p, batch_p, "bf16", "reference"))
    speeds = {ex1.device_class: 1.0,
              ex.device_class: t1.latency_s / tn.latency_s}
    if ex.device_class == ex1.device_class:   # single-device host: no split
        speeds = {ex.device_class: 1.0}

    table = CalibrationTable.from_timings(
        fit_timings, speeds=speeds,
        meta={"mode": "quick" if quick else "full", "reps": reps,
              "seq_len": ex.seq_len, "jax": jax.__version__,
              "python": platform.python_version()})

    payload = {
        "mode": "quick" if quick else "full",
        "device": jax.devices()[0].platform,
        "n_devices": ex.n_devices,
        "mesh": [list(kv) for kv in ex.mesh_key()],
        "variants": variants,
        "fit_mre_mean": fit_mre_mean,
        "analytic_mre_mean": analytic_mre_mean,
        "cache": {"lookups": cache.lookups, "hits": cache.hits,
                  "misses": cache.misses, "hit_rate": cache.hit_rate(),
                  "hit_rate_repeat": hit_rate_repeat},
        "speeds": speeds,
        "table": table.to_dict(),
    }
    save_results("stage_calibration", payload)

    rows = []
    for name, v in sorted(variants.items()):
        rows.append(("stage_calibration", f"{name}.fit_mre",
                     round(v["fit_mre"], 4), "linear-model fit error"))
        rows.append(("stage_calibration", f"{name}.analytic_mre",
                     round(v["analytic_mre"], 4),
                     "sim-to-real gap vs perf_model"))
    rows.append(("stage_calibration", "fit_mre_mean",
                 round(fit_mre_mean, 4), "gated: --max-ratio vs baseline"))
    rows.append(("stage_calibration", "analytic_mre_mean",
                 round(analytic_mre_mean, 4), "reported (CPU vs v5e model)"))
    rows.append(("stage_calibration", "cache.hit_rate_repeat",
                 round(hit_rate_repeat, 4), ">= 0.9 (gated: --min-ratio)"))
    for cls, s in speeds.items():
        rows.append(("stage_calibration", f"speed.{cls}", round(s, 3),
                     "measured device-class factor"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
