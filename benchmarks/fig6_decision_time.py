"""Fig. 6 — decision time vs pipeline complexity: IPA's solver enumerates the
configuration space (grows with stages x variants), OPD's policy forward pass
is O(|N|). Paper: OPD faster by 32.5 / 53.5 / 111.6 / 212.8 % over one
workload cycle across 4 increasingly complex pipelines.
"""
from __future__ import annotations

from benchmarks.common import save_results
from repro.api import PipelineSpec
from repro.cluster import PipelineEnv, make_trace
from repro.core import IPAPolicy, OPDTrainer, PPOConfig, OPDPolicy, run_episode

# four pipeline specs of growing decision-space size (stages x variants/stage)
PIPELINES = [
    PipelineSpec("P1-2stage", (("xlstm-125m", "whisper-small"),) * 2, quants=("bf16",)),
    PipelineSpec(
        "P2-3stage",
        (("xlstm-125m", "whisper-small", "llama3.2-1b"),) * 3,
        quants=("bf16", "int8"),
    ),
    PipelineSpec(
        "P3-4stage",
        (("xlstm-125m", "llama3.2-1b", "starcoder2-3b"),) * 4,
        quants=("bf16", "int8", "int4"),
    ),
    PipelineSpec(
        "P4-5stage",
        (("xlstm-125m", "llama3.2-1b", "starcoder2-3b"),) * 5,
        quants=("bf16", "int8", "int4"),
    ),
]


def run(quick: bool = False):
    rows, payload = [], {}
    # decision TIME per step is workload-independent; 10-20 decisions give a
    # stable mean while keeping IPA's 9^5-combo enumeration affordable
    steps = 10 if quick else 20
    for spec in PIPELINES:
        name, pipe = spec.name, spec.build()

        def make_env(seed):
            tr = make_trace("fluctuating", seed=seed, seconds=steps * 10)
            return PipelineEnv(pipe, tr, seed=seed)

        # a briefly-trained policy: decision TIME does not depend on training
        tr_ = OPDTrainer(pipe, make_env, ppo=PPOConfig(epochs=1), seed=0)
        tr_.train_episode(1)
        env = make_env(5)
        ipa = IPAPolicy(pipe)
        opd = OPDPolicy(pipe, tr_.params)
        res_ipa = run_episode(env, ipa)
        res_opd = run_episode(make_env(5), opd)
        h_ipa = res_ipa["decision_time_total"]
        h_opd = res_opd["decision_time_total"]
        speedup_pct = 100.0 * (h_ipa - h_opd) / h_opd
        n_configs = 1
        for t in pipe.tasks:
            n_configs *= len(t.variants) * pipe.f_max * pipe.b_max
        payload[name] = {
            "ipa_H_s": h_ipa,
            "opd_H_s": h_opd,
            "opd_faster_pct": speedup_pct,
            "decision_space": n_configs,
        }
        rows.append(
            (
                "fig6",
                f"{name}.opd_faster_pct",
                round(speedup_pct, 1),
                "paper: 32.5/53.5/111.6/212.8% growing with complexity",
            )
        )
    # the headline property: IPA time grows with complexity, OPD stays flat
    ipas = [payload[s.name]["ipa_H_s"] for s in PIPELINES]
    opds = [payload[s.name]["opd_H_s"] for s in PIPELINES]
    rows.append(
        (
            "fig6",
            "ipa_H_growth_x",
            round(ipas[-1] / ipas[0], 2),
            "grows with pipeline complexity",
        )
    )
    rows.append(("fig6", "opd_H_growth_x", round(opds[-1] / opds[0], 2), "stays ~flat"))
    save_results("fig6_decision_time", payload)
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
