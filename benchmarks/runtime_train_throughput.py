"""Closed-loop runtime-episode throughput: the jitted discrete-event twin
(``repro.core.runtime_vec``, a full adaptation episode — queues, batch
timeouts, cold starts, placement — compiled into one call) against the
legacy per-step loop (one Python ``RuntimeEnv``/``ServingRuntime`` step per
decision interval), at several ``num_envs``.

Metrics are episodes/s of on-policy rollout collection on the placement-aware
``serve3-hetero`` pipeline — the hot path of ``train_backend="runtime"`` PPO
training. Acceptance (ISSUE 6): >= 20x episodes/s at num_envs=32 vs the
legacy loop on CPU. The committed JSON under experiments/results/ is the
perf baseline the CI ``bench-smoke`` job gates against (fail below 0.5x).
"""
from __future__ import annotations

import platform

import jax
import numpy as np

from benchmarks.common import save_results, time_fn, time_interleaved
from repro import api
from repro.cluster import RuntimeEnv
from repro.core import OPDTrainer, PPOConfig
from repro.core import runtime_vec as rv
from repro.core import vecenv

PIPELINE = "serve3-hetero"
ARRIVALS = ("bursty", 25.0)
ENV_COUNTS = (1, 8, 32)


def run(quick: bool = False):
    horizon = 60 if quick else 120          # 6 / 12 decision steps
    legacy_eps = 2 if quick else 4
    # quick mode keeps more reps so the timed region stays long enough to
    # be stable on noisy shared CI runners (the bench-smoke gate reads it)
    vec_reps = 8 if quick else 5
    # both sides take the best of several timed passes: shared hosts steal
    # the core for whole passes at a time, and min-of-k is the standard
    # way to recover the undisturbed figure for CPU microbenchmarks
    passes = 2 if quick else 3
    kind, rate = ARRIVALS
    pipe = api.get_pipeline(PIPELINE).build()
    n_steps = max(1, horizon // 10)

    from repro.serving import make_arrivals

    def arrivals(seed):
        return make_arrivals(kind, rate=rate, seed=seed)

    def make_env(seed):
        return RuntimeEnv(pipe, arrivals(seed), horizon=horizon)

    tr = OPDTrainer(pipe, make_env, ppo=PPOConfig(), seed=0)

    # -- legacy loop: one Python RuntimeEnv step per decision interval ---
    tr._rollout(make_env(0), False)         # jit warmup outside the timing

    def legacy_pass():
        for e in range(1, legacy_eps + 1):
            tr._rollout(make_env(e), False)

    # -- runtime twin: whole closed-loop episode batches inside one jit --
    tables = vecenv.tables_from_pipeline(pipe)
    weights = tr._weights
    base_key = jax.random.PRNGKey(0)
    compile_s, vec_pass = {}, {}
    for n_envs in ENV_COUNTS:
        eps = rv.stack_episodes(
            [rv.episode_arrivals(arrivals(100 + i), horizon) for i in range(n_envs)]
        )
        keys = jax.vmap(lambda s: jax.random.fold_in(base_key, s))(np.arange(n_envs))
        args = (tr.params, tables, eps, keys)
        compile_s[n_envs] = time_fn(
            lambda args=args: rv.vec_rollout(*args, n_steps=n_steps,
                                             weights=weights),
            reps=1, warmup=0,
        ).best

        def one_pass(args=args):
            for _ in range(vec_reps):
                out = rv.vec_rollout(*args, n_steps=n_steps, weights=weights)
            jax.block_until_ready(out)
        vec_pass[n_envs] = one_pass

    # legacy and vectorized passes interleave (time_interleaved) so a
    # host-level slowdown (shared CPU, frequency drift) lands on both sides
    # of the speedup ratio instead of whichever happened to run while it
    # lasted; warmup already happened above, outside the timed region
    timings = time_interleaved(
        [legacy_pass] + [vec_pass[n] for n in ENV_COUNTS],
        reps=passes, warmup=0,
    )
    legacy_t, vec_t = timings[0], dict(zip(ENV_COUNTS, timings[1:]))

    wall = legacy_t.best
    legacy = {
        "episodes": legacy_eps,
        "wall_s": wall,
        "episodes_per_s": legacy_eps / wall,
        "steps_per_s": legacy_eps * n_steps / wall,
    }
    vec = {}
    for n_envs in ENV_COUNTS:
        wall = vec_t[n_envs].best
        vec[str(n_envs)] = {
            "episodes": n_envs * vec_reps,
            "wall_s": wall,
            "compile_s": compile_s[n_envs],
            "episodes_per_s": n_envs * vec_reps / wall,
            "steps_per_s": n_envs * vec_reps * n_steps / wall,
        }

    top = str(max(ENV_COUNTS))
    speedup = vec[top]["episodes_per_s"] / legacy["episodes_per_s"]
    payload = {
        "mode": "quick" if quick else "full",
        "pipeline": PIPELINE,
        "arrivals": {"kind": kind, "rate": rate},
        "horizon": horizon,
        "steps_per_episode": n_steps,
        "legacy": legacy,
        "vectorized": vec,
        "speedup_episodes_at_32": speedup,
        "jax": jax.__version__,
        "python": platform.python_version(),
        "device": jax.devices()[0].platform,
    }
    save_results("runtime_train_throughput", payload)

    rows = [
        (
            "runtime_train_throughput",
            "legacy.episodes_per_s",
            round(legacy["episodes_per_s"], 2),
            "",
        )
    ]
    for n_envs in ENV_COUNTS:
        rows.append(
            (
                "runtime_train_throughput",
                f"vec{n_envs}.episodes_per_s",
                round(vec[str(n_envs)]["episodes_per_s"], 2),
                "",
            )
        )
    rows.append(
        (
            "runtime_train_throughput",
            "speedup_episodes_at_32",
            round(speedup, 1),
            ">= 20x legacy loop (ISSUE 6)",
        )
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
