"""Telemetry interval-query micro-benchmark.

The control loop calls ``completed_in`` / ``arrived_in`` / ``latencies`` /
``load_history`` every adaptation interval; with linear scans those queries
were O(all records) — quadratic over a long serving run. They are now
bisect windows over sorted record arrays, so per-query cost must stay flat
as the record count grows. This benchmark measures per-query wall time on a
small and a large synthetic record stream (same shape the event loop
produces: non-decreasing virtual times) plus a real ``runtime_throughput``
-style closed-loop run, and **asserts** the large/small cost ratio stays
bounded (a linear regression would blow it up by ~record-count ratio).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_results, time_fn
from repro.serving.telemetry import Telemetry

GROWTH = 16              # large run has GROWTH x the records of the small
MAX_FLAT_RATIO = 4.0     # per-query cost may not grow ~GROWTH x


def _fill(n_records: int, rate: float = 20.0) -> Telemetry:
    """A telemetry store as the event loop would leave it after serving
    ``n_records`` requests at ``rate`` req/s of virtual time."""
    tel = Telemetry()
    rng = np.random.default_rng(0)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n_records))
    lat = rng.uniform(0.05, 1.5, size=n_records)
    for i in range(n_records):
        tel.record_arrival(float(t[i]))
    for i in range(n_records):                 # finishes non-decreasing
        tel.record_completion(i, float(t[i]), float(t[i] + lat[i]))
    return tel


def _time_queries(tel: Telemetry, horizon: float, *, repeats: int = 200) -> float:
    """Mean wall seconds of one interval's query bundle (what
    ``RuntimeEnv.step`` issues every 10 s decision) — min-of-k over the
    whole ``repeats``-bundle loop via the shared timing helper."""
    def bundle():
        for k in range(repeats):
            lo = (k % 10) * horizon / 10.0
            hi = lo + 10.0
            tel.completed_in(lo, hi)
            tel.arrived_in(lo, hi)
            tel.latencies(lo, hi)
            tel.load_history(hi, 120)

    return time_fn(bundle, reps=3, warmup=1).best / repeats


def run(quick: bool = False):
    small_n = 5_000 if quick else 20_000
    large_n = small_n * GROWTH
    rate = 20.0
    small = _time_queries(_fill(small_n, rate), small_n / rate)
    large = _time_queries(_fill(large_n, rate), large_n / rate)
    ratio = large / max(small, 1e-12)

    # a real closed-loop run (runtime_throughput-style): query cost at the
    # end of the run must match the synthetic flat profile — sanity that the
    # event loop records through the sorted fast path, not the insort
    # fallback
    from repro import api
    from repro.cluster import RuntimeEnv
    exp = api.ExperimentSpec(
        pipeline=api.get_pipeline("serve3"),
        scenario=api.replace(
            api.get_scenario("bursty"),
            rate=25.0,
            seed=11,
            horizon=60 if quick else 180,
        ),
        controller=api.get_controller("greedy"),
    )
    env = RuntimeEnv(
        exp.pipeline.build(),
        exp.scenario.build_arrivals(),
        horizon=exp.scenario.horizon,
    )
    done = False
    while not done:
        _, _, done, _ = env.step(env.default_config())
    live = _time_queries(env.runtime.telemetry, env.runtime.now, repeats=50)

    assert ratio < MAX_FLAT_RATIO, (
        f"interval-query cost grew {ratio:.1f}x across a {GROWTH}x record "
        f"growth (limit {MAX_FLAT_RATIO}x) — queries are no longer flat"
    )

    payload = {
        "small_records": small_n,
        "large_records": large_n,
        "per_query_us_small": small * 1000000.0,
        "per_query_us_large": large * 1000000.0,
        "cost_ratio": ratio,
        "max_flat_ratio": MAX_FLAT_RATIO,
        "per_query_us_live_run": live * 1000000.0,
    }
    save_results("telemetry_queries", payload)
    return [
        (
            "telemetry",
            "per_query_us_small",
            round(small * 1000000.0, 2),
            f"{small_n} records",
        ),
        (
            "telemetry",
            "per_query_us_large",
            round(large * 1000000.0, 2),
            f"{large_n} records",
        ),
        ("telemetry", "cost_ratio", round(ratio, 2), f"flat gate: < {MAX_FLAT_RATIO}"),
        (
            "telemetry",
            "per_query_us_live_run",
            round(live * 1000000.0, 2),
            "queries after a closed-loop runtime run",
        ),
    ]


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
