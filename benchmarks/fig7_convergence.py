"""Fig. 7 — OPD training convergence: training loss, value loss and mean
episode reward should all stabilise; reward should converge to a higher
value than where it started.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_results, trained_opd


def run(quick: bool = False):
    _, hist = trained_opd(episodes=12 if quick else 36)
    rewards = np.asarray(hist["reward"], dtype=np.float64)
    losses = np.asarray(hist["loss"], dtype=np.float64)
    vlosses = np.asarray(hist["value_loss"], dtype=np.float64)
    k = max(3, len(rewards) // 4)
    payload = {
        "episodes": len(rewards),
        "reward": rewards.tolist(),
        "loss": losses.tolist(),
        "value_loss": vlosses.tolist(),
        "reward_first_k": float(rewards[:k].mean()),
        "reward_last_k": float(rewards[-k:].mean()),
        "value_loss_first_k": float(vlosses[:k].mean()),
        "value_loss_last_k": float(vlosses[-k:].mean()),
    }
    save_results("fig7_convergence", payload)
    return [
        ("fig7", "episodes", len(rewards), ""),
        (
            "fig7",
            "reward_first_quarter",
            round(payload["reward_first_k"], 2),
            "reward converges to a higher value",
        ),
        (
            "fig7",
            "reward_last_quarter",
            round(payload["reward_last_k"], 2),
            "should exceed first quarter",
        ),
        (
            "fig7",
            "value_loss_first_quarter",
            round(payload["value_loss_first_k"], 4),
            "value loss decreases",
        ),
        (
            "fig7",
            "value_loss_last_quarter",
            round(payload["value_loss_last_k"], 4),
            "should be below first",
        ),
    ]


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
