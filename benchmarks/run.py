"""Benchmark harness — one module per paper table/figure plus the roofline
collector and the training-throughput benchmark.
``PYTHONPATH=src python -m benchmarks.run [--quick] [--out DIR] [--only fig3]``

Emits ``benchmark,metric,value,reference`` CSV (reference = the paper claim
the value validates against) and writes JSON payloads to experiments/results/
(or ``--out DIR``). The ``--quick`` / ``--out`` flags are shared with every
stand-alone benchmark script via ``benchmarks.common.bench_args``.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import bench_args


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated subset: "
        "fig3,fig45,fig6,fig7,roofline,runtime,train,"
        "runtime_train,telemetry,fleet,calibration",
    )
    args = bench_args(parser=ap)

    from benchmarks import (
        fig3_predictor,
        fig45_workloads,
        fig6_decision_time,
        fig7_convergence,
        fleet_throughput,
        roofline,
        runtime_throughput,
        runtime_train_throughput,
        stage_calibration,
        telemetry_queries,
        train_throughput,
    )
    suites = {
        "fig3": fig3_predictor.run,
        "fig45": fig45_workloads.run,
        "fig6": fig6_decision_time.run,
        "fig7": fig7_convergence.run,
        "roofline": roofline.run,
        "runtime": runtime_throughput.run,
        "train": train_throughput.run,
        "runtime_train": runtime_train_throughput.run,
        "telemetry": telemetry_queries.run,
        "fleet": fleet_throughput.run,
        "calibration": stage_calibration.run,
    }
    wanted = args.only.split(",") if args.only else list(suites)
    print("benchmark,metric,value,reference")
    failures = []
    for name in wanted:
        t0 = time.time()
        try:
            rows = suites[name](quick=args.quick)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failures.append((name, e))
            print(f"{name},ERROR,{type(e).__name__}: {e},", file=sys.stderr)
            continue
        for r in rows:
            print(",".join(str(x).replace(",", ";") for x in r))
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {[n for n, _ in failures]}")


if __name__ == "__main__":
    main()
