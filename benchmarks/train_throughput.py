"""Training-rollout throughput: the vectorized pure-JAX engine
(``repro.core.vecenv``, one jitted scan-over-vmap call per episode batch)
against the legacy per-step Python loop (one NumPy ``PipelineEnv`` step per
iteration), at several ``num_envs``.

Metrics are environment steps/s and episodes/s of on-policy rollout
collection — the hot path PPO training spends its time in. Acceptance
(ISSUE 3): >= 10x episodes/s at num_envs=32 vs the legacy loop on CPU. The
committed JSON under experiments/results/ is the perf baseline the CI
``bench-smoke`` job gates against (fail below 0.5x).
"""
from __future__ import annotations

import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_results
from repro import api
from repro.cluster import PipelineEnv
from repro.core import OPDTrainer, PPOConfig
from repro.core import vecenv

PIPELINE = "paper-4stage"
SCENARIO = "fluctuating"
ENV_COUNTS = (1, 8, 32)


def run(quick: bool = False):
    seconds = 300 if quick else 1200        # 30 / 120 decision steps
    legacy_eps = 2 if quick else 4
    # quick mode keeps more reps so the timed region stays long enough to
    # be stable on noisy shared CI runners (the bench-smoke gate reads it)
    vec_reps = 10 if quick else 5
    scen = api.get_scenario(SCENARIO)
    pipe = api.get_pipeline(PIPELINE).build()

    def make_env(seed):
        return PipelineEnv(pipe, scen.train_trace(seed, seconds=seconds), seed=seed)

    tr = OPDTrainer(pipe, make_env, ppo=PPOConfig(), seed=0)
    env0 = make_env(0)
    n_steps = env0.n_steps

    # -- legacy loop: one Python iteration per env step ------------------
    tr._rollout(env0, False)                # jit warmup outside the timing
    t0 = time.perf_counter()
    for e in range(1, legacy_eps + 1):
        tr._rollout(make_env(e), False)
    wall = time.perf_counter() - t0
    legacy = {
        "episodes": legacy_eps,
        "wall_s": wall,
        "episodes_per_s": legacy_eps / wall,
        "steps_per_s": legacy_eps * n_steps / wall,
    }

    # -- vectorized engine: scan episodes, vmap envs ---------------------
    tables = vecenv.tables_from_pipeline(pipe)
    weights = env0.w
    base_key = jax.random.PRNGKey(0)
    vec = {}
    for n_envs in ENV_COUNTS:
        traces = jnp.asarray(
            np.stack([make_env(100 + i).trace for i in range(n_envs)]),
            jnp.float32,
        )
        keys = jax.vmap(lambda s: jax.random.fold_in(base_key, s))(jnp.arange(n_envs))
        args = (tr.params, tables, traces, keys)
        t0 = time.perf_counter()
        jax.block_until_ready(
            vecenv.vec_rollout(*args, n_steps=n_steps, weights=weights)
        )
        compile_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(vec_reps):
            out = vecenv.vec_rollout(*args, n_steps=n_steps, weights=weights)
        jax.block_until_ready(out)
        wall = time.perf_counter() - t0
        vec[str(n_envs)] = {
            "episodes": n_envs * vec_reps,
            "wall_s": wall,
            "compile_s": compile_s,
            "episodes_per_s": n_envs * vec_reps / wall,
            "steps_per_s": n_envs * vec_reps * n_steps / wall,
        }

    top = str(max(ENV_COUNTS))
    speedup = vec[top]["episodes_per_s"] / legacy["episodes_per_s"]
    payload = {
        "mode": "quick" if quick else "full",
        "pipeline": PIPELINE,
        "scenario": SCENARIO,
        "steps_per_episode": n_steps,
        "legacy": legacy,
        "vectorized": vec,
        "speedup_episodes_at_32": speedup,
        "jax": jax.__version__,
        "python": platform.python_version(),
        "device": jax.devices()[0].platform,
    }
    save_results("train_throughput", payload)

    rows = [
        ("train_throughput", "legacy.steps_per_s", round(legacy["steps_per_s"], 1), "")
    ]
    for n_envs in ENV_COUNTS:
        rows.append(
            (
                "train_throughput",
                f"vec{n_envs}.steps_per_s",
                round(vec[str(n_envs)]["steps_per_s"], 1),
                "",
            )
        )
    rows.append(
        (
            "train_throughput",
            "speedup_episodes_at_32",
            round(speedup, 1),
            ">= 10x legacy loop (ISSUE 3)",
        )
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
