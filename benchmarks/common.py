"""Shared benchmark plumbing: CLI flags, result paths, OPD policy training
cache, CSV emission. Every benchmark module exposes ``run(quick: bool) ->
list[row]`` where a row is (benchmark, metric, value, reference) —
``reference`` is the paper's claim the value should be compared against (or
"" if none) — and a ``__main__`` that delegates to ``bench_main`` so the
``--quick`` / ``--out DIR`` flags behave identically everywhere.
"""
from __future__ import annotations

import argparse
import json
import os
import pickle

import numpy as np

# the ONE timing loop (min-of-k, warmup + block_until_ready) every benchmark
# shares with the stage executor — canonical home is repro.timing so src-side
# code can use it without importing benchmarks
from repro.timing import Timing, time_fn, time_interleaved  # noqa: F401

RESULTS_DIR = os.path.join("experiments", "results")
POLICY_CACHE = os.path.join("experiments", "opd_policy.pkl")

_OUT_DIR: str | None = None          # --out override, set by bench_args


def results_dir() -> str:
    return _OUT_DIR or RESULTS_DIR


def set_results_dir(path: str | None) -> None:
    """Redirect ``save_results`` (benchmarks' JSON payloads) to ``path`` —
    CI points this at an artifact dir so committed baselines in
    experiments/results/ are never clobbered by a CI run."""
    global _OUT_DIR
    _OUT_DIR = path


def bench_args(argv=None, *, description: str | None = None,
               parser: argparse.ArgumentParser | None = None):
    """The flags every benchmark script shares: ``--quick`` (CI-sized
    episode/epoch counts) and ``--out DIR`` (JSON destination). Pass a
    pre-built ``parser`` to stack script-specific flags on top."""
    ap = parser or argparse.ArgumentParser(description=description)
    ap.add_argument(
        "--quick",
        action="store_true",
        help="reduced episode/epoch counts (CI-sized)",
    )
    ap.add_argument(
        "--out",
        default=None,
        metavar="DIR",
        help=f"write JSON results here (default {RESULTS_DIR})",
    )
    args = ap.parse_args(argv)
    if args.out:
        set_results_dir(args.out)
    return args


def bench_main(run, argv=None, *, parser=None, kwargs_from_args=None) -> None:
    """Shared ``__main__`` driver: parse the common flags, invoke
    ``run(quick=...)``, emit the benchmark,metric,value,reference CSV.
    Scripts with extra flags pass a pre-built ``parser`` plus
    ``kwargs_from_args(args) -> dict`` to thread them into ``run``."""
    args = bench_args(argv, parser=parser)
    kwargs = kwargs_from_args(args) if kwargs_from_args else {}
    print("benchmark,metric,value,reference")
    for r in run(quick=args.quick, **kwargs):
        print(",".join(str(x).replace(",", ";") for x in r))


def save_results(name: str, payload: dict) -> None:
    os.makedirs(results_dir(), exist_ok=True)
    with open(os.path.join(results_dir(), name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=_np_default)


def _np_default(o):
    if isinstance(o, (np.floating, np.integer)):
        return o.item()
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def trained_opd(episodes: int = 36, *, seed: int = 0, force: bool = False,
                log=print, pipeline=None, cache_tag: str | None = None):
    """Train (or load cached) OPD policy on the paper's three workload
    regimes, round-robin over episodes. Returns (params, trainer_history).

    ``pipeline`` (a PipelineSpec; default the registered "paper-4stage")
    selects the pipeline — pass a cluster-bearing spec for placement-aware
    training, together with a distinct ``cache_tag`` (the policy's input
    layout grows per-node features, so caches are not interchangeable)."""
    from repro import api
    from repro.cluster import PipelineEnv
    from repro.core import OPDTrainer, PPOConfig

    cache = POLICY_CACHE if cache_tag is None else os.path.join(
        "experiments",
        f"opd_policy_{cache_tag}.pkl",
    )
    if not force and os.path.exists(cache):
        with open(cache, "rb") as f:
            blob = pickle.load(f)
        if blob.get("episodes", 0) >= episodes:
            return blob["params"], blob["history"]

    spec = pipeline or api.get_pipeline("paper-4stage")
    pipe = spec.build()
    kinds = ("steady_low", "fluctuating", "steady_high")

    def make_env(seed_):
        scen = api.get_scenario(kinds[seed_ % 3])
        return PipelineEnv(pipe, scen.train_trace(seed_), seed=seed_)

    tr = OPDTrainer(pipe, make_env, ppo=PPOConfig(expert_freq=4), seed=seed)
    for e in range(1, episodes + 1):
        tr.train_episode(e, env_seed=e)
        if log and (e % 6 == 0 or e == 1):
            log(
                f"  opd episode {e:3d}/{episodes} "
                f"reward={tr.history['reward'][-1]:9.2f} "
                f"loss={tr.history['loss'][-1]:8.4f} "
                f"expert={tr.history['expert'][-1]}"
            )
    os.makedirs(os.path.dirname(cache), exist_ok=True)
    with open(cache, "wb") as f:
        pickle.dump(
            {"params": tr.params, "history": tr.history, "episodes": episodes},
            f,
        )
    return tr.params, tr.history
