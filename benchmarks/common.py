"""Shared benchmark plumbing: result paths, OPD policy training cache,
CSV emission. Every fig*.py module exposes ``run(quick: bool) -> list[row]``
where a row is (benchmark, metric, value, reference) — ``reference`` is the
paper's claim the value should be compared against (or "" if none).
"""
from __future__ import annotations

import json
import os
import pickle

import numpy as np

RESULTS_DIR = os.path.join("experiments", "results")
POLICY_CACHE = os.path.join("experiments", "opd_policy.pkl")


def save_results(name: str, payload: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=_np_default)


def _np_default(o):
    if isinstance(o, (np.floating, np.integer)):
        return o.item()
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def trained_opd(episodes: int = 36, *, seed: int = 0, force: bool = False,
                log=print):
    """Train (or load cached) OPD policy on the paper's three workload
    regimes, round-robin over episodes. Returns (params, trainer_history)."""
    from repro import api
    from repro.cluster import PipelineEnv
    from repro.core import OPDTrainer, PPOConfig

    if not force and os.path.exists(POLICY_CACHE):
        with open(POLICY_CACHE, "rb") as f:
            blob = pickle.load(f)
        if blob.get("episodes", 0) >= episodes:
            return blob["params"], blob["history"]

    pipe = api.get_pipeline("paper-4stage").build()
    kinds = ("steady_low", "fluctuating", "steady_high")

    def make_env(seed_):
        scen = api.get_scenario(kinds[seed_ % 3])
        return PipelineEnv(pipe, scen.train_trace(seed_), seed=seed_)

    tr = OPDTrainer(pipe, make_env, ppo=PPOConfig(expert_freq=4), seed=seed)
    for e in range(1, episodes + 1):
        tr.train_episode(e, env_seed=e)
        if log and (e % 6 == 0 or e == 1):
            log(f"  opd episode {e:3d}/{episodes} "
                f"reward={tr.history['reward'][-1]:9.2f} "
                f"loss={tr.history['loss'][-1]:8.4f} "
                f"expert={tr.history['expert'][-1]}")
    os.makedirs(os.path.dirname(POLICY_CACHE), exist_ok=True)
    with open(POLICY_CACHE, "wb") as f:
        pickle.dump({"params": tr.params, "history": tr.history,
                     "episodes": episodes}, f)
    return tr.params, tr.history
