"""CI perf gate: fail when a fresh benchmark result regresses against a
fraction of the committed baseline.

    python -m benchmarks.gate CURRENT.json \\
        --baseline experiments/results/train_throughput.json \\
        --metric vectorized.32.steps_per_s --min-ratio 0.5 \\
        --metric fit_mre_mean --max-ratio 4.0

``--metric`` is a dotted path into the JSON payload; repeat it to gate
several metrics in one invocation (one comparison per pair, every failure
reported before exiting). ``--min-ratio`` gates higher-is-better metrics
(throughput): pass when current >= ratio * baseline. ``--max-ratio`` gates
lower-is-better metrics (calibration error, latency percentiles): pass
when current <= ratio * baseline. Thresholds pair positionally with the
metrics in command-line order; give exactly one threshold total to
broadcast it across all metrics. Null, NaN and zero metric values are hard
errors — each would otherwise make the ratio comparison silently
meaningless.
"""

import argparse
import json
import math
import sys

DEFAULT_METRIC = "vectorized.32.steps_per_s"


class _Ordered(argparse.Action):
    """Append (dest, value) to a shared event list so --min-ratio and
    --max-ratio keep their command-line order relative to the metrics."""

    def __call__(self, parser, namespace, values, option_string=None):
        events = getattr(namespace, "events", None)
        if events is None:
            events = []
            namespace.events = events
        events.append((self.dest, values))


def lookup(payload: dict, dotted: str) -> float:
    node = payload
    for part in dotted.split("."):
        node = node[part]
    if node is None:
        raise SystemExit(
            f"GATE ERROR: metric {dotted!r} is null (nothing was measured)"
        )
    value = float(node)
    if math.isnan(value):
        # a NaN silently loses every comparison — fail loudly instead of
        # letting `ratio >= min_ratio` pass or fail by accident
        raise SystemExit(f"GATE ERROR: metric {dotted!r} is NaN")
    if value == 0.0:
        # a zero baseline makes every candidate pass (ratio = inf) and a
        # zero candidate can only mean nothing ran — both are measurement
        # bugs, not regressions; refuse to compare
        raise SystemExit(f"GATE ERROR: metric {dotted!r} is zero")
    return value


def pair_events(events) -> list[tuple[str, str, float]]:
    """-> [(metric, kind, threshold)] with kind in {"min", "max"}.

    The i-th threshold event (of either kind) pairs with the i-th metric;
    a single threshold broadcasts across all metrics; no thresholds means
    --min-ratio 0.5 on everything (the historical default).
    """
    metrics = [v for d, v in events if d == "metric"] or [DEFAULT_METRIC]
    thresholds = [(("min" if d == "min_ratio" else "max"), v)
                  for d, v in events if d in ("min_ratio", "max_ratio")]
    if not thresholds:
        thresholds = [("min", 0.5)]
    if len(thresholds) == 1:
        thresholds = thresholds * len(metrics)
    if len(thresholds) != len(metrics):
        raise SystemExit(
            f"GATE ERROR: {len(metrics)} --metric but {len(thresholds)} "
            f"--min-ratio/--max-ratio (give one per metric, or one total)"
        )
    return [(m, k, v) for m, (k, v) in zip(metrics, thresholds, strict=True)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh result JSON (e.g. from --out DIR)")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument(
        "--metric",
        action=_Ordered,
        help="dotted metric path (repeatable)",
    )
    ap.add_argument(
        "--min-ratio",
        action=_Ordered,
        type=float,
        help="higher-is-better threshold: fail when current < ratio * "
        "baseline; one per --metric, or a single value broadcast across "
        "all metrics (default 0.5)",
    )
    ap.add_argument(
        "--max-ratio",
        action=_Ordered,
        type=float,
        help="lower-is-better threshold: fail when current > ratio * "
        "baseline; pairs with --metric like --min-ratio",
    )
    args = ap.parse_args(argv)
    comparisons = pair_events(getattr(args, "events", None) or [])

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failed = 0
    for metric, kind, threshold in comparisons:
        cur = lookup(current, metric)
        base = lookup(baseline, metric)
        ratio = cur / base
        ok = ratio >= threshold if kind == "min" else ratio <= threshold
        failed += 0 if ok else 1
        status = "OK" if ok else "REGRESSION"
        print(
            f"{status}: {metric} current={cur:.4g} baseline={base:.4g} "
            f"ratio={ratio:.2f} vs {kind}-ratio={threshold}"
        )
    return 0 if failed == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
