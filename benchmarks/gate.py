"""CI perf gate: fail when a fresh benchmark result regresses below a
fraction of the committed baseline.

    python -m benchmarks.gate CURRENT.json \\
        --baseline experiments/results/train_throughput.json \\
        --metric vectorized.32.steps_per_s --min-ratio 0.5

``--metric`` is a dotted path into the JSON payload. Higher is better; the
gate passes when current >= min-ratio * baseline.
"""

import argparse
import json
import math
import sys

DEFAULT_METRIC = "vectorized.32.steps_per_s"


def lookup(payload: dict, dotted: str) -> float:
    node = payload
    for part in dotted.split("."):
        node = node[part]
    if node is None:
        raise SystemExit(
            f"GATE ERROR: metric {dotted!r} is null (nothing was measured)"
        )
    value = float(node)
    if math.isnan(value):
        # a NaN silently loses every comparison — fail loudly instead of
        # letting `ratio >= min_ratio` pass or fail by accident
        raise SystemExit(f"GATE ERROR: metric {dotted!r} is NaN")
    return value


def load_metric(path: str, dotted: str) -> float:
    with open(path) as f:
        return lookup(json.load(f), dotted)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh result JSON (e.g. from --out DIR)")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--metric", default=DEFAULT_METRIC, help="dotted metric path")
    ap.add_argument("--min-ratio", type=float, default=0.5, help="fail threshold")
    args = ap.parse_args(argv)

    cur = load_metric(args.current, args.metric)
    base = load_metric(args.baseline, args.metric)
    ratio = cur / base if base else float("inf")
    ok = ratio >= args.min_ratio
    status = "OK" if ok else "REGRESSION"
    print(f"{status}: {args.metric} current={cur:.1f} baseline={base:.1f}")
    print(f"ratio={ratio:.2f} vs min-ratio={args.min_ratio}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
