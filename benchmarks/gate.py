"""CI perf gate: fail when a fresh benchmark result regresses below a
fraction of the committed baseline.

    python -m benchmarks.gate CURRENT.json \\
        --baseline experiments/results/train_throughput.json \\
        --metric vectorized.32.steps_per_s --min-ratio 0.5 \\
        --metric speedup_episodes_at_32 --min-ratio 0.5

``--metric`` is a dotted path into the JSON payload; repeat it to gate
several metrics in one invocation (one comparison per pair, every failure
reported before exiting). ``--min-ratio`` pairs positionally with the
metrics; give exactly one to broadcast it across all of them. Higher is
better; a comparison passes when current >= min-ratio * baseline. Null,
NaN and zero metric values are hard errors — each would otherwise make the
ratio comparison silently meaningless.
"""

import argparse
import json
import math
import sys

DEFAULT_METRIC = "vectorized.32.steps_per_s"


def lookup(payload: dict, dotted: str) -> float:
    node = payload
    for part in dotted.split("."):
        node = node[part]
    if node is None:
        raise SystemExit(
            f"GATE ERROR: metric {dotted!r} is null (nothing was measured)"
        )
    value = float(node)
    if math.isnan(value):
        # a NaN silently loses every comparison — fail loudly instead of
        # letting `ratio >= min_ratio` pass or fail by accident
        raise SystemExit(f"GATE ERROR: metric {dotted!r} is NaN")
    if value == 0.0:
        # a zero baseline makes every candidate pass (ratio = inf) and a
        # zero candidate can only mean nothing ran — both are measurement
        # bugs, not regressions; refuse to compare
        raise SystemExit(f"GATE ERROR: metric {dotted!r} is zero")
    return value


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh result JSON (e.g. from --out DIR)")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument(
        "--metric",
        action="append",
        default=None,
        help="dotted metric path (repeatable)",
    )
    ap.add_argument(
        "--min-ratio",
        action="append",
        type=float,
        default=None,
        help="fail threshold; one per --metric, or a single value broadcast "
        "across all metrics (default 0.5)",
    )
    args = ap.parse_args(argv)

    metrics = args.metric or [DEFAULT_METRIC]
    ratios = args.min_ratio or [0.5]
    if len(ratios) == 1:
        ratios = ratios * len(metrics)
    if len(ratios) != len(metrics):
        raise SystemExit(
            f"GATE ERROR: {len(metrics)} --metric but {len(ratios)} "
            f"--min-ratio (give one per metric, or one total)"
        )

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failed = 0
    for metric, min_ratio in zip(metrics, ratios, strict=True):
        cur = lookup(current, metric)
        base = lookup(baseline, metric)
        ratio = cur / base
        ok = ratio >= min_ratio
        failed += 0 if ok else 1
        status = "OK" if ok else "REGRESSION"
        print(
            f"{status}: {metric} current={cur:.1f} baseline={base:.1f} "
            f"ratio={ratio:.2f} vs min-ratio={min_ratio}"
        )
    return 0 if failed == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
