"""Multi-tenant fleet serving throughput: the registered 3-tenant fleet on
the heterogeneous edge cell, with every tenant's arrival rate cranked to
drive 1e5 (quick) to ~1e6 requests through the shared event loop. Reports
per-tenant p50/p95/p99 and shed rate, fleet-wide shed rate, and the event
loop's wall-clock processing rate (events/s — the fleet's simulation
throughput).

``inv_p99`` (1/p99 seconds, higher is better) is emitted per tenant so the
ratio gate in CI can guard tail latency regressions with the same
"candidate/baseline >= min-ratio" arithmetic as the throughput metrics.
"""

from __future__ import annotations

from benchmarks.common import save_results
from repro import api

# per-tenant arrival rates (req/s) sized so the quick run offers >1e5
# requests over its horizon while the cluster, fully allocated, can still
# serve the large majority (shed stays a reported tail, not the bulk)
QUICK_RATES = {"interactive": 400.0, "analytics": 300.0, "batch": 250.0}
QUICK_HORIZON = 120
FULL_HORIZON = 1200
ADMISSION_LIMIT = 3000.0


def _scaled_spec(horizon: int):
    spec = api.get_fleet("fleet-3tenant-hetero")
    tenants = tuple(
        api.replace(
            t,
            scenario=api.replace(
                t.scenario, rate=QUICK_RATES[t.name], horizon=horizon
            ),
        )
        for t in spec.tenants
    )
    return api.replace(
        spec,
        name=f"{spec.name}-bench",
        tenants=tenants,
        admission_limit=ADMISSION_LIMIT,
    )


def run(quick: bool = False):
    horizon = QUICK_HORIZON if quick else FULL_HORIZON
    spec = _scaled_spec(horizon)
    sess = api.FleetSession.from_spec(spec)
    rep = sess.serve()
    s, wall = rep["summary"], rep["serve_wall_s"]

    fleet = s["fleet"]
    payload = {
        "fleet": {
            "tenants": fleet["tenants"],
            "horizon_s": horizon,
            "offered": fleet["offered"],
            "requests": fleet["served"],
            "shed": fleet["shed"],
            "shed_rate": fleet["shed_rate"],
            "events": fleet["events"],
            "events_per_s": fleet["events_per_s"],
            "virtual_time_s": fleet["virtual_time_s"],
            "wall_s": wall,
            "reallocations": fleet["reallocations"],
        },
        "tenants": {},
    }

    def ms(v):
        return None if v is None else v * 1e3

    rows = [
        (
            "fleet",
            "fleet.requests",
            fleet["served"],
            "completed requests across all tenants",
        ),
        (
            "fleet",
            "fleet.events_per_s",
            round(fleet["events_per_s"], 0),
            "shared event-loop processing rate",
        ),
        (
            "fleet",
            "fleet.shed_rate",
            round(fleet["shed_rate"], 4),
            "fleet-wide load-shedding fraction",
        ),
    ]
    for name, t in s["tenants"].items():
        res = {
            "offered": t["arrived"],
            "served": t["served"],
            "shed": t["shed"],
            "shed_rate": t["shed_rate"],
            "priority": t["priority"],
            "share": t["share"],
            "p50_ms": ms(t["p50"]),
            "p95_ms": ms(t["p95"]),
            "p99_ms": ms(t["p99"]),
            "inv_p99": None if t["p99"] is None else 1.0 / t["p99"],
        }
        payload["tenants"][name] = res
        rows += [
            (
                "fleet",
                f"{name}.p99_ms",
                None if res["p99_ms"] is None else round(res["p99_ms"], 1),
                "per-tenant tail latency on the shared cluster",
            ),
            (
                "fleet",
                f"{name}.shed_rate",
                round(res["shed_rate"], 4),
                "priority-graded load shedding",
            ),
        ]

    floor = 100_000
    assert fleet["served"] >= floor, (
        f"fleet completed only {fleet['served']} requests (< {floor}); "
        f"the benchmark must exercise CI-scale load"
    )
    save_results("fleet_throughput", payload)
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
