"""Figs. 4-5 — cost & QoS of Random / Greedy / IPA / OPD across the three
workload regimes, one 1200 s cycle each (120 decisions at the paper's 10 s
adaptation interval).

Paper claims validated here:
  steady_low : OPD cost ~2.2x greedy, QoS +36% vs greedy;
               vs IPA: cost -16%, QoS -3.8%
  fluctuating: OPD cost +37% vs greedy, QoS +21% vs greedy;
               vs IPA: cost -6%, QoS -3%
  steady_high: greedy/IPA/OPD converge to similar cost & QoS

``--cluster NAME`` re-runs the sweep with the pipeline placed on a
registered (heterogeneous) cluster topology — node speed factors, per-node
feasibility and cross-node hops change the physics, so these rows carry no
paper reference; the JSON lands in ``fig45_workloads_<cluster>.json``.

The default (homogeneous) run additionally lands the reactive-vs-proactive
comparison on the event-driven runtime: bursty and ramp arrivals served by
(a) the reactive OPD policy, (b) the reactive demand-matched min-cost
controller (``capacity``), (c) the proactive capacity controller — the
same inner behind a multi-horizon LSTM forecaster
(``scenario.predictor="lstm-multi"``) whose next-interval forecast
replaces the last-second load estimate, wrapped in ``ProactiveController``
so burst variants are pre-warmed before the burst lands — and (d) the
proactive accuracy-first expert as an ablation. The headline proactive
arm (c) must cut p95/p99 against the reactive OPD baseline at equal or
lower cost; (b) isolates the forecast+pre-warm contribution from the
inner controller choice.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_results, trained_opd
from repro import api

EVAL_SEED = 77

# the proactive comparison's operating point: burst (1.8x) and ramp peak
# (2.4x) exceed the reactive configuration's capacity while the base load
# fits — the regime where acting one adaptation interval ahead matters
PROACTIVE_RATE = 60.0
PROACTIVE_ARMS = (
    ("reactive_opd", "opd", None),
    ("reactive_capacity", "capacity", None),
    ("proactive_capacity", "proactive-capacity", "lstm-multi"),
    ("proactive_expert", "proactive-expert", "lstm-multi"),
)


def _serving_episode(kind, name, params, pipeline, *, horizon, predictor):
    """One event-driven serving run of controller ``name`` on the runtime
    backend; ``predictor`` names a registered PredictorSpec (the Session
    trains the forecaster and attaches it to the env)."""
    scen = api.replace(api.get_scenario(kind), rate=PROACTIVE_RATE,
                       seed=EVAL_SEED, horizon=horizon, predictor=predictor)
    exp = api.ExperimentSpec(
        pipeline=pipeline,
        scenario=scen,
        controller=api.replace(api.get_controller(name), seed=EVAL_SEED),
        backend="runtime",
    )
    sess = api.Session.from_spec(exp)
    if name == "opd":
        sess.with_params(params)
    rep = sess.serve()
    s = rep["summary"]
    return {
        "p50": s["p50"], "p95": s["p95"], "p99": s["p99"],
        "cost": float(np.mean(rep["cost"])),
        "served": s["served"],
        "switches": s["switches"],
        "prewarms": s["prewarms"],
    }


def _proactive_section(params, pipeline, quick):
    """Reactive-vs-proactive on bursty/ramp; returns (payload, rows)."""
    horizon = 160 if quick else 300
    payload, rows = {}, []
    for kind in ("bursty", "ramp"):
        res = {arm: _serving_episode(kind, name, params, pipeline,
                                     horizon=horizon, predictor=pred)
               for arm, name, pred in PROACTIVE_ARMS}
        payload[kind] = res
        base, pro = res["reactive_opd"], res["proactive_capacity"]
        rows += [
            ("fig45", f"proactive.{kind}.p99_s", round(pro["p99"], 2),
             f"reactive opd {base['p99']:.2f}"),
            ("fig45", f"proactive.{kind}.p95_s", round(pro["p95"], 2),
             f"reactive opd {base['p95']:.2f}"),
            ("fig45", f"proactive.{kind}.cost", round(pro["cost"], 2),
             f"reactive opd {base['cost']:.2f}"),
            ("fig45", f"proactive.{kind}.prewarms", pro["prewarms"], ""),
        ]
    return payload, rows


def _episode(kind, name, params, pipeline, horizon=None):
    """One workload cycle of controller ``name``, declared via repro.api."""
    scen = api.replace(api.get_scenario(kind), seed=EVAL_SEED)
    if horizon is not None:
        scen = api.replace(scen, horizon=horizon)
    exp = api.ExperimentSpec(
        pipeline=pipeline,
        scenario=scen,
        controller=api.replace(api.get_controller(name), seed=EVAL_SEED),
        backend="analytic",
    )
    sess = api.Session.from_spec(exp)
    if name == "opd":
        sess.with_params(params)     # shared agent, trained on all regimes
    return sess.serve()


def run(quick: bool = False, cluster: str | None = None):
    pipeline = api.get_pipeline("paper-4stage")
    if cluster:
        pipeline = api.replace(pipeline, cluster=api.get_cluster(cluster))
    params, _ = trained_opd(
        episodes=12 if quick else 36,
        pipeline=pipeline if cluster else None,
        cache_tag=cluster,
    )
    # the heterogeneous quick sweep is CI-sized: one regime, shorter cycle
    kinds = ("fluctuating",) if cluster and quick else (
        "steady_low",
        "fluctuating",
        "steady_high",
    )
    horizon = 400 if cluster and quick else None
    rows, payload = [], {}
    for kind in kinds:
        res = {}
        for name in ("random", "greedy", "ipa", "opd"):
            ep = _episode(kind, name, params, pipeline, horizon)
            cost = np.asarray(ep["cost"])
            qos = np.asarray(ep["qos"])
            res[name] = {
                "cost": float(cost.mean()),
                "qos": float(qos.mean()),
                "cost_std": float(cost.std()),
                "qos_std": float(qos.std()),
                "reward": float(np.mean(ep["rewards"])),
            }
        payload[kind] = res
        g, i, o = res["greedy"], res["ipa"], res["opd"]
        bench = "fig45" if not cluster else f"fig45@{cluster}"

        def ref(claims):
            return "" if cluster else claims[kind]

        rows += [
            (
                bench,
                f"{kind}.opd_cost_vs_greedy_pct",
                round(100 * (o["cost"] / max(g["cost"], 1e-09) - 1), 1),
                ref(
                    {"steady_low": "+120%", "fluctuating": "+37%", "steady_high": "~0%"}
                ),
            ),
            (
                bench,
                f"{kind}.opd_qos_vs_greedy_pct",
                round(100 * _rel(o["qos"], g["qos"]), 1),
                ref(
                    {"steady_low": "+36%", "fluctuating": "+21%", "steady_high": "~0%"}
                ),
            ),
            (
                bench,
                f"{kind}.opd_cost_vs_ipa_pct",
                round(100 * (o["cost"] / max(i["cost"], 1e-09) - 1), 1),
                ref({"steady_low": "-16%", "fluctuating": "-6%", "steady_high": "~0%"}),
            ),
            (
                bench,
                f"{kind}.opd_qos_vs_ipa_pct",
                round(100 * _rel(o["qos"], i["qos"]), 1),
                ref(
                    {"steady_low": "-3.8%", "fluctuating": "-3%", "steady_high": "~0%"}
                ),
            ),
        ]
    if not cluster:
        payload["proactive"], pro_rows = _proactive_section(
            params, pipeline, quick)
        rows += pro_rows
    save_results("fig45_workloads" + (f"_{cluster}" if cluster else ""), payload)
    return rows


def _rel(a: float, b: float) -> float:
    """Relative QoS change robust to sign/near-zero baselines."""
    return (a - b) / max(abs(b), 1e-9)


if __name__ == "__main__":
    import argparse

    from benchmarks.common import bench_main
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--cluster",
        default=None,
        choices=api.list_clusters(),
        help="place the pipeline on a registered cluster "
        "topology (default: homogeneous scalar pool)",
    )
    bench_main(run, parser=ap, kwargs_from_args=lambda a: {"cluster": a.cluster})
