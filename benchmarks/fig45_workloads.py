"""Figs. 4-5 — cost & QoS of Random / Greedy / IPA / OPD across the three
workload regimes, one 1200 s cycle each (120 decisions at the paper's 10 s
adaptation interval).

Paper claims validated here:
  steady_low : OPD cost ~2.2x greedy, QoS +36% vs greedy;
               vs IPA: cost -16%, QoS -3.8%
  fluctuating: OPD cost +37% vs greedy, QoS +21% vs greedy;
               vs IPA: cost -6%, QoS -3%
  steady_high: greedy/IPA/OPD converge to similar cost & QoS
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_results, trained_opd
from repro import api

EVAL_SEED = 77


def _episode(kind, name, params):
    """One workload cycle of controller ``name``, declared via repro.api."""
    exp = api.ExperimentSpec(
        pipeline=api.get_pipeline("paper-4stage"),
        scenario=api.replace(api.get_scenario(kind), seed=EVAL_SEED),
        controller=api.replace(api.get_controller(name), seed=EVAL_SEED),
        backend="analytic")
    sess = api.Session.from_spec(exp)
    if name == "opd":
        sess.with_params(params)     # shared agent, trained on all regimes
    return sess.serve()


def run(quick: bool = False):
    params, _ = trained_opd(episodes=12 if quick else 36)
    rows, payload = [], {}
    for kind in ("steady_low", "fluctuating", "steady_high"):
        res = {}
        for name in ("random", "greedy", "ipa", "opd"):
            ep = _episode(kind, name, params)
            cost = np.asarray(ep["cost"])
            qos = np.asarray(ep["qos"])
            res[name] = {"cost": float(cost.mean()),
                         "qos": float(qos.mean()),
                         "cost_std": float(cost.std()),
                         "qos_std": float(qos.std()),
                         "reward": float(np.mean(ep["rewards"]))}
        payload[kind] = res
        g, i, o = res["greedy"], res["ipa"], res["opd"]
        rows += [
            ("fig45", f"{kind}.opd_cost_vs_greedy_pct",
             round(100 * (o["cost"] / max(g["cost"], 1e-9) - 1), 1),
             {"steady_low": "+120%", "fluctuating": "+37%",
              "steady_high": "~0%"}[kind]),
            ("fig45", f"{kind}.opd_qos_vs_greedy_pct",
             round(100 * _rel(o["qos"], g["qos"]), 1),
             {"steady_low": "+36%", "fluctuating": "+21%",
              "steady_high": "~0%"}[kind]),
            ("fig45", f"{kind}.opd_cost_vs_ipa_pct",
             round(100 * (o["cost"] / max(i["cost"], 1e-9) - 1), 1),
             {"steady_low": "-16%", "fluctuating": "-6%",
              "steady_high": "~0%"}[kind]),
            ("fig45", f"{kind}.opd_qos_vs_ipa_pct",
             round(100 * _rel(o["qos"], i["qos"]), 1),
             {"steady_low": "-3.8%", "fluctuating": "-3%",
              "steady_high": "~0%"}[kind]),
        ]
    save_results("fig45_workloads", payload)
    return rows


def _rel(a: float, b: float) -> float:
    """Relative QoS change robust to sign/near-zero baselines."""
    return (a - b) / max(abs(b), 1e-9)


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
