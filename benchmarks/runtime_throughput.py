"""Event-driven runtime throughput: requests/sec across arrival scenarios,
simulation speed (virtual seconds per wall second), tail latency, and the
control loop's decision-to-effect latency (wall time from invoking the
controller to the configuration being live in the runtime; variant switches
additionally pay COLD_START_SECONDS of virtual unavailability).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import save_results
from repro.cluster import RuntimeEnv
from repro.cluster.perf_model import make_pipeline
from repro.configs import ARCHS
from repro.core import GreedyPolicy
from repro.serving import SCENARIOS, make_arrivals
from repro.serving.runtime import COLD_START_SECONDS


def _pipe():
    return make_pipeline(
        [[ARCHS["xlstm-125m"], ARCHS["whisper-small"]],
         [ARCHS["llama3.2-1b"], ARCHS["starcoder2-3b"]],
         [ARCHS["granite-moe-3b-a800m"], ARCHS["zamba2-2.7b"]]],
        name="runtime3", quants=("bf16",))


def run(quick: bool = False):
    horizon = 60 if quick else 180
    pipe = _pipe()
    rows, payload = [], {}
    for name in SCENARIOS:
        env = RuntimeEnv(pipe, make_arrivals(name, rate=25.0, seed=11),
                         horizon=horizon)
        policy = GreedyPolicy(pipe)
        done = False
        effect_ms, switches = [], 0
        wall0 = time.perf_counter()
        while not done:
            t0 = time.perf_counter()
            cfg = policy(env)                    # decision (wall)
            decide_s = time.perf_counter() - t0
            _, _, done, info = env.step(cfg)     # applies, then simulates
            # decision-to-effect excludes the interval simulation itself
            effect_ms.append((decide_s + info["apply_wall_s"]) * 1e3)
            switches += info["switched"]
        summary = env.drain()
        wall = time.perf_counter() - wall0
        res = {
            "submitted": env.submitted,
            "served": summary["served"],
            "virtual_rps": summary["throughput_rps"],
            "wall_rps": summary["served"] / max(wall, 1e-9),
            "sim_speedup_x": env.runtime.now / max(wall, 1e-9),
            "p50_ms": summary["p50"] * 1e3,
            "p95_ms": summary["p95"] * 1e3,
            "p99_ms": summary["p99"] * 1e3,
            "mean_batch": summary["mean_batch_size"],
            "decision_to_effect_ms": float(np.mean(effect_ms)),
            "switches": switches,
            "cold_start_s": COLD_START_SECONDS,
        }
        payload[name] = res
        rows += [
            ("runtime", f"{name}.virtual_rps", round(res["virtual_rps"], 1),
             "served request rate in virtual time"),
            ("runtime", f"{name}.wall_rps", round(res["wall_rps"], 0),
             "event-loop processing rate"),
            ("runtime", f"{name}.p95_ms", round(res["p95_ms"], 1),
             "tail latency under the greedy controller"),
            ("runtime", f"{name}.decision_to_effect_ms",
             round(res["decision_to_effect_ms"], 2),
             "controller invocation -> config live"),
        ]
        assert summary["served"] == env.submitted, \
            f"{name}: dropped {env.submitted - summary['served']} requests"
    save_results("runtime_throughput", payload)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
