"""Event-driven runtime throughput: requests/sec across arrival scenarios,
simulation speed (virtual seconds per wall second), tail latency, and the
control loop's decision-to-effect latency (wall time from invoking the
controller to the configuration being live in the runtime; variant switches
additionally pay COLD_START_SECONDS of virtual unavailability).

Runs are declared through ``repro.api``: the registered "serve3" pipeline ×
every arrival scenario × the greedy controller, one Session each.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_results
from repro import api
from repro.serving import SCENARIOS
from repro.serving.runtime import COLD_START_SECONDS


def run(quick: bool = False):
    horizon = 60 if quick else 180
    rows, payload = [], {}
    for name in SCENARIOS:
        exp = api.ExperimentSpec(
            pipeline=api.get_pipeline("serve3"),
            scenario=api.replace(
                api.get_scenario(name),
                rate=25.0,
                seed=11,
                horizon=horizon,
            ),
            controller=api.get_controller("greedy"),
        )
        apply_wall, switches = [], 0

        def on_step(env, cfg, info):
            nonlocal switches
            apply_wall.append(info["apply_wall_s"])
            switches += info["switched"]

        sess = api.Session.from_spec(exp)
        rep = sess.serve(on_step=on_step)
        summary, wall = rep["summary"], rep["serve_wall_s"]
        effect_ms = [
            (d + a) * 1000.0
            for (d, a) in zip(rep["decide_wall_s"], apply_wall, strict=True)
        ]
        def ms(v):
            # summary percentiles are None (not NaN) when nothing completed
            return None if v is None else v * 1e3

        res = {
            "submitted": summary["submitted"],
            "served": summary["served"],
            "virtual_rps": summary["throughput_rps"],
            "wall_rps": summary["served"] / max(wall, 1e-09),
            "sim_speedup_x": summary["virtual_now"] / max(wall, 1e-09),
            "p50_ms": ms(summary["p50"]),
            "p95_ms": ms(summary["p95"]),
            "p99_ms": ms(summary["p99"]),
            "mean_batch": summary["mean_batch_size"],
            "decision_to_effect_ms": float(np.mean(effect_ms)),
            "switches": switches,
            "cold_start_s": COLD_START_SECONDS,
        }
        payload[name] = res
        rows += [
            (
                "runtime",
                f"{name}.virtual_rps",
                round(res["virtual_rps"], 1),
                "served request rate in virtual time",
            ),
            (
                "runtime",
                f"{name}.wall_rps",
                round(res["wall_rps"], 0),
                "event-loop processing rate",
            ),
            (
                "runtime",
                f"{name}.p95_ms",
                None if res["p95_ms"] is None else round(res["p95_ms"], 1),
                "tail latency under the greedy controller",
            ),
            (
                "runtime",
                f"{name}.decision_to_effect_ms",
                round(res["decision_to_effect_ms"], 2),
                "controller invocation -> config live",
            ),
        ]
        assert summary["served"] == summary[
            "submitted"
        ], f"{name}: dropped {summary['submitted'] - summary['served']} requests"
    save_results("runtime_throughput", payload)
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)
