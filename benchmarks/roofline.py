"""Roofline collector — reads the dry-run records under experiments/dryrun/
and emits the per-(arch x shape x mesh) roofline table for EXPERIMENTS.md
§Roofline: three terms in seconds, dominant bottleneck, and the
MODEL_FLOPS / HLO_FLOPS usefulness ratio.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save_results

DRYRUN_DIR = os.path.join("experiments", "dryrun")


def load_records(mesh: str | None = "16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh is None or r.get("mesh") == mesh:
            recs.append(r)
    return recs


def table(mesh: str = "16x16"):
    """-> list of row dicts (only OK records), sorted worst-first by the
    dominant-term wall time."""
    rows = []
    for r in load_records(mesh):
        if r["status"] != "OK":
            rows.append(
                {
                    "arch": r["arch"],
                    "shape": r["shape"],
                    "mesh": r["mesh"],
                    "status": r["status"],
                    "reason": r.get("reason", r.get("error", "")),
                }
            )
            continue
        t = r["roofline"]
        rows.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "mesh": r["mesh"],
                "status": "OK",
                "compute_s": t["compute_s"],
                "memory_s": t["memory_s"],
                "collective_s": t["collective_s"],
                "dominant": t["dominant"],
                "useful_flops_ratio": r["useful_flops_ratio"],
                "peak_gb_per_dev": r["memory"]["peak_bytes"] / 1000000000.0,
                "step_time_bound_s": max(
                    t["compute_s"],
                    t["memory_s"],
                    t["collective_s"],
                ),
                "roofline_fraction": t["compute_s"] / max(
                    t["compute_s"],
                    t["memory_s"],
                    t["collective_s"],
                    1e-30,
                ),
            }
        )
    ok = [x for x in rows if x["status"] == "OK"]
    ok.sort(key=lambda x: -x["step_time_bound_s"])
    return ok + [x for x in rows if x["status"] != "OK"]


def run(quick: bool = False):
    rows = []
    tab = table("16x16")
    oks = [x for x in tab if x["status"] == "OK"]
    if not oks:
        return [("roofline", "records", 0, "run launch/dryrun first")]
    save_results("roofline_16x16", {"rows": tab})
    by_dom = {}
    for x in oks:
        by_dom[x["dominant"]] = by_dom.get(x["dominant"], 0) + 1
    rows.append(("roofline", "records_ok", len(oks), "39 live combos"))
    rows.append(
        (
            "roofline",
            "dominant_split",
            "/".join((f"{k}:{v}" for (k, v) in sorted(by_dom.items()))),
            "",
        )
    )
    worst = oks[0]
    rows.append(
        (
            "roofline",
            "slowest_pair",
            f"{worst['arch']}|{worst['shape']}",
            f"bound {worst['step_time_bound_s']:.3f}s dom={worst['dominant']}",
        )
    )
    best_frac = max(oks, key=lambda x: x["roofline_fraction"])
    rows.append(
        (
            "roofline",
            "best_compute_fraction",
            f"{best_frac['arch']}|{best_frac['shape']}"
            f"={best_frac['roofline_fraction']:.2f}",
            "",
        )
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main(run)


def markdown(mesh: str = "16x16", baseline_dir: str | None = None) -> str:
    """EXPERIMENTS.md §Roofline table (optionally with baseline deltas)."""
    import os

    rows = table(mesh)
    base = {}
    if baseline_dir:
        global DRYRUN_DIR
        keep = DRYRUN_DIR
        DRYRUN_DIR = baseline_dir
        try:
            base = {
                (x["arch"], x["shape"]): x for x in table(mesh) if x["status"] == "OK"
            }
        finally:
            DRYRUN_DIR = keep
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| useful | peak GB/dev |"
    )
    sep = "|---|---|---|---|---|---|---|---|"
    out = [hdr, sep]
    for x in rows:
        if x["status"] != "OK":
            out.append(
                f"| {x['arch']} | {x['shape']} | — | — | — | "
                f"{x['status']}: {x['reason']} | — | — |"
            )
            continue

        def fmt(key, unit=1.0, nd=4):
            v = x[key] * unit
            b = base.get((x["arch"], x["shape"]))
            if b and b[key] > 0 and abs(v / (b[key] * unit) - 1) > 0.05:
                return f"{v:.{nd}g} ({v / (b[key] * unit):.2g}x)"
            return f"{v:.{nd}g}"

        out.append(
            f"| {x['arch']} | {x['shape']} | {fmt('compute_s')} | "
            f"{fmt('memory_s')} | {fmt('collective_s')} | "
            f"{x['dominant'].replace('_s', '')} | "
            f"{x['useful_flops_ratio']:.2f} | {x['peak_gb_per_dev']:.1f} |"
        )
    return "\n".join(out)
