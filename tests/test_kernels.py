"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles,
in interpret mode (kernel bodies execute on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def rand(shape, dtype):
    return jax.random.normal(KEY, shape, jnp.float32).astype(dtype)


FA_SHAPES = [
    # (B, S, H, Hkv, D)
    (1, 128, 4, 2, 64),
    (2, 256, 8, 8, 64),
    (1, 256, 6, 2, 128),
    (2, 128, 4, 1, 80),  # non-128 head_dim (zamba2-style)
]


@pytest.mark.parametrize("B,S,H,Hkv,D", FA_SHAPES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 4e-2)])
def test_flash_attention_causal(B, S, H, Hkv, D, dtype, tol):
    q = rand((B, S, H, D), dtype)
    k = rand((B, S, Hkv, D), dtype)
    v = rand((B, S, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert out.shape == want.shape and out.dtype == want.dtype
    assert jnp.abs(out.astype(jnp.float32) - want.astype(jnp.float32)).max() < tol


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_window(window):
    q = rand((1, 256, 4, 64), jnp.float32)
    k = rand((1, 256, 2, 64), jnp.float32)
    v = rand((1, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    assert jnp.abs(out - want).max() < 2e-3


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shape_invariance(bq, bk):
    q = rand((1, 256, 4, 64), jnp.float32)
    k = rand((1, 256, 4, 64), jnp.float32)
    v = rand((1, 256, 4, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v)
    assert jnp.abs(out - want).max() < 2e-3


DEC_SHAPES = [
    # (B, H, Hkv, D, C, n_valid)
    (2, 8, 2, 64, 1024, 700),
    (1, 24, 8, 128, 2048, 2048),
    (4, 4, 4, 64, 512, 100),
    (2, 32, 8, 128, 1024, 1),  # single valid slot
]


@pytest.mark.parametrize("B,H,Hkv,D,C,nv", DEC_SHAPES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 4e-2)])
def test_decode_attention(B, H, Hkv, D, C, nv, dtype, tol):
    q = rand((B, 1, H, D), dtype)
    k = rand((B, C, Hkv, D), dtype)
    v = rand((B, C, Hkv, D), dtype)
    mask = jnp.arange(C)[None, :] < jnp.full((B, 1), nv)
    out = ops.decode_attention(q, k, v, mask)
    want = ref.decode_attention_ref(q, k, v, mask)
    assert out.shape == want.shape
    assert jnp.abs(out.astype(jnp.float32) - want.astype(jnp.float32)).max() < tol


def test_decode_attention_ragged_batch():
    """Each sequence has a different valid length (real serving batch)."""
    B, H, Hkv, D, C = 3, 8, 4, 64, 512
    q = rand((B, 1, H, D), jnp.float32)
    k = rand((B, C, Hkv, D), jnp.float32)
    v = rand((B, C, Hkv, D), jnp.float32)
    nv = jnp.array([[37], [512], [256]])
    mask = jnp.arange(C)[None, :] < nv
    out = ops.decode_attention(q, k, v, mask)
    want = ref.decode_attention_ref(q, k, v, mask)
    assert jnp.abs(out - want).max() < 2e-3


def test_flash_matches_model_attention_path():
    """cfg.use_flash=True routes model attention through the kernels and
    must reproduce the jnp path."""
    from repro.configs import ARCHS
    from repro.models import api
    cfg = ARCHS["llama3.2-1b"].smoke().replace(
        d_model=256,
        n_heads=4,
        n_kv=2,
        n_layers=2,
    )
    cfg_f = cfg.replace(use_flash=True)
    p = api.init_model(KEY, cfg)
    batch = {"tokens": jnp.arange(2 * 128).reshape(2, 128) % cfg.vocab}
    lg, _ = api.forward(p, batch, cfg)
    lf, _ = api.forward(p, batch, cfg_f)
    assert jnp.abs(lg - lf).max() < 5e-3
