"""Tests for repro.analysis: every reprolint rule (RPL001-RPL006) on seeded
caught/clean fixture pairs, suppression handling, the CLI gate on the repo's
own tree, and the checkify sanitizer (repro.analysis.sanitize) wired around
the jitted twins — a sanitized episode must still match the reference env."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.analysis import RULES, analyze_source, sanitize
from repro.analysis.cli import main as reprolint_main
from repro.api.session import Session
from repro.cluster import PipelineEnv, make_trace
from repro.core import action_to_config, head_sizes, init_policy
from repro.core import runtime_vec as rv
from repro.core import vecenv
from repro.core.mdp import QoSWeights
from repro.serving import make_arrivals

REPO = Path(__file__).resolve().parents[1]
WEIGHTS = QoSWeights()

# a path inside a jit-pure package, so RPL002/RPL005 fixtures are in scope
TWIN = "src/repro/train/fixture.py"


def codes(src, path="fixture.py"):
    return {f.rule for f in analyze_source(src, path)}


class TestRuleCatalogue:
    def test_all_rules_registered(self):
        assert set(RULES) == {"RPL001", "RPL002", "RPL003", "RPL004",
                              "RPL005", "RPL006"}


class TestKeyReuse:
    def test_catches_plain_reuse(self):
        src = (
            "import jax\n"
            "key = jax.random.PRNGKey(0)\n"
            "a = jax.random.normal(key, (2,))\n"
            "b = jax.random.uniform(key)\n"
        )
        found = analyze_source(src, "fixture.py")
        assert [f.rule for f in found] == ["RPL001"]
        assert found[0].line == 4
        assert "'key'" in found[0].message

    def test_catches_loop_carried_reuse(self):
        src = (
            "import jax\n"
            "def draws(key, n):\n"
            "    out = []\n"
            "    for _ in range(n):\n"
            "        out.append(jax.random.normal(key, (2,)))\n"
            "    return out\n"
        )
        assert "RPL001" in codes(src)

    def test_clean_split_chain(self):
        src = (
            "import jax\n"
            "key = jax.random.PRNGKey(0)\n"
            "key, sub = jax.random.split(key)\n"
            "a = jax.random.normal(sub, (2,))\n"
            "key, sub = jax.random.split(key)\n"
            "b = jax.random.uniform(sub)\n"
        )
        assert "RPL001" not in codes(src)

    def test_clean_branch_exclusive_use(self):
        src = (
            "import jax\n"
            "def f(key, flag):\n"
            "    if flag:\n"
            "        return jax.random.normal(key, (2,))\n"
            "    else:\n"
            "        return jax.random.uniform(key)\n"
        )
        assert "RPL001" not in codes(src)


class TestHostNumerics:
    def test_catches_numpy_in_jitted_fn(self):
        src = (
            "import jax\n"
            "import numpy as np\n"
            "@jax.jit\n"
            "def f(x):\n"
            "    return np.sum(x)\n"
        )
        found = [f for f in analyze_source(src, TWIN) if f.rule == "RPL002"]
        assert any("NumPy" in f.message for f in found)

    def test_catches_float_cast_and_branch(self):
        src = (
            "import jax\n"
            "import jax.numpy as jnp\n"
            "@jax.jit\n"
            "def f(x):\n"
            "    if jnp.sum(x) > 0:\n"
            "        return float(x[0])\n"
            "    return x\n"
        )
        msgs = [f.message for f in analyze_source(src, TWIN)]
        assert any("branch" in m for m in msgs)
        assert any("float()" in m for m in msgs)

    def test_catches_scan_body(self):
        src = (
            "import jax\n"
            "import time\n"
            "def body(carry, x):\n"
            "    return carry, time.perf_counter()\n"
            "def run(xs):\n"
            "    return jax.lax.scan(body, 0.0, xs)\n"
        )
        found = [f for f in analyze_source(src, TWIN) if f.rule == "RPL002"]
        assert any("clock" in f.message for f in found)

    def test_clean_host_side_helper(self):
        # float()/np use outside traced code is fine even in a twin module
        src = (
            "import jax\n"
            "import jax.numpy as jnp\n"
            "def summarize(x):\n"
            "    return float(x.mean())\n"
            "@jax.jit\n"
            "def f(x):\n"
            "    return jnp.where(jnp.sum(x) > 0, x * 2.0, x)\n"
        )
        assert "RPL002" not in codes(src, TWIN)

    def test_out_of_scope_module_not_flagged(self):
        src = (
            "import jax\n"
            "import numpy as np\n"
            "@jax.jit\n"
            "def f(x):\n"
            "    return np.sum(x)\n"
        )
        assert "RPL002" not in codes(src, "src/repro/serving/telemetry.py")


class TestCompatBypass:
    def test_catches_raw_make_mesh(self):
        src = 'import jax\nmesh = jax.make_mesh((2, 2), ("a", "b"))\n'
        found = [f for f in analyze_source(src, "f.py") if f.rule == "RPL003"]
        assert found and "repro.compat.make_mesh" in found[0].message

    def test_catches_shard_map_import(self):
        src = "from jax.experimental.shard_map import shard_map\n"
        assert "RPL003" in codes(src)

    def test_catches_raw_cost_analysis(self):
        src = "stats = compiled.cost_analysis()\n"
        found = [f for f in analyze_source(src, "f.py") if f.rule == "RPL003"]
        assert found and "repro.compat.cost_analysis" in found[0].message

    def test_clean_compat_usage(self):
        src = (
            "from repro.compat import cost_analysis, make_mesh, shard_map\n"
            'mesh = make_mesh((2, 2), ("a", "b"))\n'
            "stats = cost_analysis(compiled)\n"
        )
        assert "RPL003" not in codes(src)

    def test_compat_module_itself_exempt(self):
        src = "import jax\nf = jax.make_mesh\n"
        assert "RPL003" not in codes(src, "src/repro/compat.py")


class TestSpecSafety:
    def test_catches_unfrozen_untyped_spec(self):
        src = (
            "from dataclasses import dataclass\n"
            "@dataclass\n"
            "class FooSpec:\n"
            "    x: object\n"
        )
        msgs = [f.message for f in analyze_source(src, "f.py")]
        assert any("frozen=True" in m for m in msgs)
        assert any("to_dict" in m for m in msgs)
        assert any("from_dict" in m for m in msgs)
        assert any("not JSON-safe" in m for m in msgs)

    def test_clean_spec(self):
        src = (
            "from dataclasses import dataclass\n"
            "@dataclass(frozen=True)\n"
            "class FooSpec:\n"
            "    name: str\n"
            "    sizes: tuple[int, ...]\n"
            "    child: BarSpec | None\n"
            "    def to_dict(self):\n"
            "        return {}\n"
            "    @classmethod\n"
            "    def from_dict(cls, d):\n"
            "        return cls(**d)\n"
        )
        assert "RPL004" not in codes(src)

    def test_non_spec_class_ignored(self):
        src = "class Helper:\n    x: object\n"
        assert "RPL004" not in codes(src)


class TestCpuLoopLowering:
    def test_catches_dynamic_scatter(self):
        src = (
            "import jax.numpy as jnp\n"
            "def f(x, i, v):\n"
            "    return x.at[i].set(v)\n"
        )
        found = [f for f in analyze_source(src, TWIN) if f.rule == "RPL005"]
        assert found and found[0].severity == "warning"

    def test_catches_sum_cumprod(self):
        src = (
            "import jax.numpy as jnp\n"
            "def f(m):\n"
            "    return jnp.sum(jnp.cumprod(m, axis=-1), axis=-1)\n"
        )
        found = [f for f in analyze_source(src, TWIN) if f.rule == "RPL005"]
        assert found and "argmin" in found[0].message

    def test_clean_static_index_and_argmin(self):
        src = (
            "import jax.numpy as jnp\n"
            "def f(x, m, i, v):\n"
            "    y = x.at[0].set(1.0)\n"
            "    z = x.at[i].add(v)\n"
            "    return y, z, jnp.argmin(m, axis=-1)\n"
        )
        assert "RPL005" not in codes(src, TWIN)

    def test_out_of_scope_module_not_flagged(self):
        src = "def f(x, i, v):\n    return x.at[i].set(v)\n"
        assert "RPL005" not in codes(src, "src/repro/serving/runtime.py")


class TestTimedRegionSync:
    BENCH = "benchmarks/fixture.py"

    def test_catches_sync_in_perf_counter_window(self):
        src = (
            "import time\n"
            "import numpy as np\n"
            "def run(step, x):\n"
            "    t0 = time.perf_counter()\n"
            "    out = step(x)\n"
            "    v = out.item()\n"
            "    host = np.asarray(out)\n"
            "    wall = time.perf_counter() - t0\n"
            "    return wall, v, host\n"
        )
        found = [f for f in analyze_source(src, self.BENCH)
                 if f.rule == "RPL006"]
        assert len(found) == 2 and found[0].severity == "error"

    def test_catches_sync_in_fn_handed_to_timer(self):
        src = (
            "from benchmarks.common import time_fn\n"
            "def run(step, x):\n"
            "    def one_pass():\n"
            "        return step(x).item()\n"
            "    return time_fn(one_pass, reps=3).best\n"
        )
        found = [f for f in analyze_source(src, self.BENCH)
                 if f.rule == "RPL006"]
        assert found and ".item()" in found[0].message

    def test_clean_sync_outside_window(self):
        # syncs after the clock stops (the stop statement reads t0) are fine
        src = (
            "import time\n"
            "import numpy as np\n"
            "def run(step, x):\n"
            "    t0 = time.perf_counter()\n"
            "    out = step(x)\n"
            "    wall = time.perf_counter() - t0\n"
            "    return wall, float(np.asarray(out).mean())\n"
        )
        assert "RPL006" not in codes(src, self.BENCH)

    def test_only_benchmark_paths_in_scope(self):
        src = (
            "import time\n"
            "def run(step, x):\n"
            "    t0 = time.perf_counter()\n"
            "    v = step(x).item()\n"
            "    return time.perf_counter() - t0, v\n"
        )
        assert "RPL006" in codes(src, self.BENCH)
        assert "RPL006" not in codes(src, "src/repro/launch/dryrun.py")

    def test_executor_module_is_jit_pure_scope(self):
        # the measured stage executor joined RPL002's jit-pure set
        src = "import numpy as np\n"
        assert "RPL002" in codes(src, "src/repro/cluster/executor.py")


class TestSuppression:
    BAD = (
        "import jax\n"
        "key = jax.random.PRNGKey(0)\n"
        "a = jax.random.normal(key, (2,))\n"
        "b = jax.random.uniform(key){}\n"
    )

    def test_line_ignore_silences(self):
        src = self.BAD.format("  # reprolint: ignore[RPL001] on purpose")
        assert "RPL001" not in codes(src)

    def test_line_ignore_wrong_code_still_fires(self):
        src = self.BAD.format("  # reprolint: ignore[RPL999]")
        assert "RPL001" in codes(src)

    def test_file_ignore_silences(self):
        src = "# reprolint: ignore-file[RPL001]\n" + self.BAD.format("")
        assert "RPL001" not in codes(src)

    def test_marker_inside_string_does_not_suppress(self):
        src = self.BAD.format(' + str("# reprolint: ignore[RPL001]")')
        assert "RPL001" in codes(src)


class TestCli:
    def test_repo_src_is_clean(self, capsys):
        assert reprolint_main([str(REPO / "src")]) == 0
        out = capsys.readouterr().out
        assert "0 error(s)" in out

    def test_error_finding_exits_nonzero(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text('import jax\nm = jax.make_mesh((2, 2), ("a", "b"))\n')
        assert reprolint_main([str(bad)]) == 1
        assert "RPL003" in capsys.readouterr().out

    def test_warning_exits_zero_unless_strict(self, tmp_path, capsys):
        warn = tmp_path / "train"
        warn.mkdir()
        f = warn / "w.py"
        f.write_text("def f(x, i, v):\n    return x.at[i].set(v)\n")
        assert reprolint_main([str(f)]) == 0
        assert reprolint_main([str(f), "--strict"]) == 1
        capsys.readouterr()

    def test_json_output(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text('import jax\nm = jax.make_mesh((2, 2), ("a", "b"))\n')
        reprolint_main([str(bad), "--json"])
        findings = json.loads(capsys.readouterr().out)
        assert findings[0]["rule"] == "RPL003"
        assert findings[0]["severity"] == "error"
        assert findings[0]["line"] == 2

    def test_select_and_list_rules(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text('import jax\nm = jax.make_mesh((2, 2), ("a", "b"))\n')
        assert reprolint_main([str(bad), "--select", "RPL001"]) == 0
        assert reprolint_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for code in RULES:
            assert code in out

    def test_unparseable_file_reported(self, tmp_path, capsys):
        bad = tmp_path / "broken.py"
        bad.write_text("def f(:\n")
        assert reprolint_main([str(bad)]) == 1
        assert "RPL000" in capsys.readouterr().out


class TestCheckifySanitizer:
    def test_checkify_off_by_default(self):
        assert not sanitize.enabled()

    def test_checkify_env_flag(self, monkeypatch):
        monkeypatch.setenv(sanitize.ENV_FLAG, "1")
        assert sanitize.enabled()
        monkeypatch.setenv(sanitize.ENV_FLAG, "0")
        assert not sanitize.enabled()

    def test_checkify_scope_wins_over_env(self, monkeypatch):
        monkeypatch.setenv(sanitize.ENV_FLAG, "1")
        with sanitize.enabled_scope(False):
            assert not sanitize.enabled()
        assert sanitize.enabled()

    def test_checkify_nan_raises(self):
        @sanitize.checked
        def bad(x):
            return jnp.log(x)

        assert np.isnan(float(bad(jnp.float32(-1.0))))  # off: silent NaN
        with sanitize.enabled_scope():
            with pytest.raises(Exception, match="nan"):
                bad(jnp.float32(-1.0))

    def test_checkify_oob_raises(self):
        @sanitize.checked
        def gather(x, i):
            return x[i]

        with sanitize.enabled_scope():
            with pytest.raises(Exception, match="out-of-bounds"):
                gather(jnp.arange(4.0), jnp.int32(9))

    def test_checkify_vecenv_episode_matches_reference(self, monkeypatch):
        """A REPRO_CHECKIFY=1 vecenv episode completes and its rewards match
        the reference PipelineEnv stepping the same action sequence."""
        monkeypatch.setenv(sanitize.ENV_FLAG, "1")
        pipe = api.get_pipeline("serve2").build()
        tables = vecenv.tables_from_pipeline(pipe)
        trace = make_trace("fluctuating", seed=3, seconds=150)
        params = init_policy(jax.random.PRNGKey(0), pipe.n_tasks * 9, head_sizes(pipe))
        traj = vecenv.rollout(
            params,
            tables,
            jnp.asarray(trace, jnp.float32),
            jax.random.PRNGKey(7),
            n_steps=15,
            weights=WEIGHTS,
        )
        env = PipelineEnv(pipe, trace, seed=0)
        for t, action in enumerate(np.asarray(traj["actions"])):
            _, r_ref, _, _ = env.step(action_to_config(pipe, action))
            assert np.isclose(r_ref, float(traj["rewards"][t]), rtol=0.0001, atol=0.05)

    def test_checkify_runtime_replay_matches_reference(self, monkeypatch):
        """A REPRO_CHECKIFY=1 runtime-twin replay completes and matches the
        reference RuntimeEnv on per-interval reward."""
        from repro.cluster import RuntimeEnv

        monkeypatch.setenv(sanitize.ENV_FLAG, "1")
        pipe = api.get_pipeline("serve2").build()
        tables = vecenv.tables_from_pipeline(pipe)
        arrivals = make_arrivals("bursty", rate=20, seed=3)
        rng = np.random.default_rng(0)
        sizes = head_sizes(pipe)
        actions = np.stack(
            [[rng.integers(0, s) for s in sizes] for _ in range(6)]
        ).astype(np.int32)

        env = RuntimeEnv(pipe, arrivals, horizon=60)
        ref_r = []
        for a in actions:
            _, r, _, _ = env.step(action_to_config(pipe, a))
            ref_r.append(float(r))

        ep = rv.episode_arrivals(arrivals, 60)
        out = rv.replay(tables, ep, jnp.asarray(actions), n_steps=6, weights=WEIGHTS)
        assert np.allclose(np.asarray(out["rewards"]), ref_r, atol=0.15)

    def test_checkify_session_toggle(self):
        spec = api.ExperimentSpec(
            pipeline=api.get_pipeline("serve2"),
            scenario=api.get_scenario("steady_low"),
            controller=api.get_controller("random"),
        )
        sess = Session(spec, debug_checkify=True)
        with sess._sanitize_scope():
            assert sanitize.enabled()
        assert not sanitize.enabled()
        off = Session(spec)
        with off._sanitize_scope():
            assert not sanitize.enabled()
