"""Guarded hypothesis import: the real library when installed (see
requirements.txt), otherwise a minimal seeded random-sampling fallback so the
property tests still collect and run meaningful example sweeps. Tests import
``given``/``settings``/``st`` from here instead of hypothesis directly.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _FALLBACK_MAX_EXAMPLES = 25   # keep the no-hypothesis sweep fast

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple((s.draw(rng) for s in strategies)))

        @staticmethod
        def lists(strategy, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [
                    strategy.draw(rng)
                    for _ in range(int(rng.integers(min_size, max_size + 1)))
                ]
            )

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                n = min(
                    getattr(wrapper, "_max_examples", _FALLBACK_MAX_EXAMPLES),
                    _FALLBACK_MAX_EXAMPLES,
                )
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strategies), **kwargs)
            # copy identity WITHOUT functools.wraps: __wrapped__ would make
            # pytest read the original signature and demand fixtures for the
            # drawn arguments
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper
        return deco

    def settings(max_examples=_FALLBACK_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
