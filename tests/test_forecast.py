"""Multi-horizon load forecasting + proactive pre-warm control:
dataset windowing, backbone parity, spec plumbing, prewarm semantics and
Eq. 5 observation-shape pinning."""
import dataclasses
import json

import numpy as np
import pytest

from repro import api
from repro.cluster.env import PipelineEnv
from repro.cluster.monitor import Monitor
from repro.cluster.perf_model import make_pipeline
from repro.configs import ARCHS
from repro.core import forecast
from repro.core.controller import Observation
from repro.core.expert import CapacityPolicy, ExpertPolicy, capacity_config
from repro.core.mdp import Config
from repro.core.predictor import HISTORY, HORIZON, train_predictor
from repro.core.proactive import ProactiveController
from repro.serving.runtime import COLD_START_SECONDS, ServingRuntime


def sinusoid(seed=0, seconds=700, period=60.0):
    t = np.arange(seconds, dtype=np.float32)
    rng = np.random.default_rng(seed)
    return (60.0 + 40.0 * np.sin(2 * np.pi * t / period)
            + rng.normal(0.0, 1.5, seconds).astype(np.float32))


def two_stage_pipe():
    return make_pipeline(
        [[ARCHS["whisper-small"], ARCHS["xlstm-125m"]],
         [ARCHS["llama3.2-1b"], ARCHS["starcoder2-3b"]]],
        quants=("bf16",),
    )


# ------------------------------------------------------------- dataset ----


def test_dataset_windowing_shapes():
    traces = [np.arange(300, dtype=np.float32)]
    X, y, scales = forecast.make_forecast_dataset(
        traces, history=120, horizons=(5, 10, 20, 60), scale=100.0)
    assert X.shape == (300 - 120 - 60 + 1, 120, 1)
    assert y.shape == (len(X), 4)
    assert scales.shape == (1,)


def test_dataset_multichannel_scales():
    rng = np.random.default_rng(0)
    tele = rng.uniform(0.0, 50.0, size=(400, 5)).astype(np.float32)
    X, y, scales = forecast.make_forecast_dataset(
        [tele], history=120, horizons=(5, 10), scale=100.0)
    assert X.shape == (400 - 120 - 10 + 1, 120, 5)
    assert scales[0] == 100.0 and scales.shape == (5,)
    # every channel normalised into [-1, 1]
    assert np.abs(X).max() <= 1.0 + 1e-6
    # re-using the returned scales reproduces the same normalisation
    X2, _, _ = forecast.make_forecast_dataset(
        [tele], history=120, horizons=(5, 10), scale=100.0,
        channel_scales=scales)
    np.testing.assert_allclose(X, X2)


def test_dataset_targets_are_per_horizon_max():
    # a single spike at t=125 shows up only in windows whose horizon
    # reaches it; everything else predicts the flat level
    tr = np.full(200, 10.0, dtype=np.float32)
    tr[125] = 90.0
    X, y, _ = forecast.make_forecast_dataset(
        [tr], history=120, horizons=(2, 10), scale=100.0)
    # window starting at 0 covers future (120, 130]: the spike is 6 s out —
    # beyond h=2, inside h=10
    assert y[0, 0] == pytest.approx(0.10)
    assert y[0, 1] == pytest.approx(0.90)
    # window starting at 4 has the spike 2 s out: inside both horizons
    assert y[4, 0] == pytest.approx(0.90)


def test_empty_dataset_raises():
    with pytest.raises(ValueError, match="empty forecast dataset"):
        forecast.train_forecaster([np.ones(50, np.float32)], scale=10.0)


# ---------------------------------------------------- backbone parity ----


@pytest.mark.parametrize("backbone", forecast.BACKBONES)
def test_backbones_learn_sinusoid(backbone):
    traces = [sinusoid(seed=s) for s in range(2)]
    params, ch = forecast.train_forecaster(
        traces, backbone=backbone, scale=100.0, epochs=6,
        lr={"lstm": 5e-3, "mlstm": 3e-3}[backbone], seed=0)
    sm = forecast.smape_horizons(params, [sinusoid(seed=9)],
                                 backbone=backbone, scale=100.0,
                                 channel_scales=ch)
    assert set(sm) == set(forecast.HORIZONS)
    # loose parity bound: both backbones must clearly beat a naive
    # constant-mean forecast (~35% SMAPE on this sinusoid)
    assert np.mean(list(sm.values())) < 25.0


def test_forecast_fn_adapter():
    traces = [sinusoid(seed=0, seconds=400)]
    params, ch = forecast.train_forecaster(traces, scale=100.0, epochs=2)
    fn = forecast.as_forecast_fn(params, scale=100.0,
                                 channel_scales=ch)
    assert fn.horizons == forecast.HORIZONS
    assert fn.min_history == forecast.HISTORY
    out = fn(sinusoid(seed=1, seconds=200))
    assert out.shape == (len(forecast.HORIZONS),)
    assert np.all(np.isfinite(out))


def test_short_batch_clamp_trains():
    # dataset smaller than the default batch=256 must still take steps
    traces = [sinusoid(seed=0, seconds=200)]     # 21 windows
    params, _ = forecast.train_forecaster(traces, scale=100.0, epochs=1)
    assert np.isfinite(float(np.asarray(params["out"]["b"]).sum()))


# --------------------------------------------- predictor regression ----


def test_train_predictor_short_trace_regression():
    # traces shorter than batch=64 windows used to return untrained params
    # silently; the clamp must train and change the output head
    rng = np.random.default_rng(0)
    tr = rng.uniform(10, 50, HISTORY + HORIZON + 8).astype(np.float32)
    params = train_predictor([tr], scale=60.0, epochs=2, log=None)
    assert params is not None


def test_train_predictor_empty_raises():
    with pytest.raises(ValueError, match="empty predictor dataset"):
        train_predictor([np.ones(10, np.float32)], scale=10.0, log=None)


def test_predictor_fn_advertises_min_history():
    from repro.core.predictor import as_predictor_fn
    rng = np.random.default_rng(0)
    tr = rng.uniform(10, 50, HISTORY + HORIZON + 8).astype(np.float32)
    params = train_predictor([tr], scale=60.0, epochs=1, log=None)
    fn = as_predictor_fn(params, scale=60.0)
    # the envs use this to fall back to last-observed load while the
    # monitor window is still constant-padded (Monitor.valid)
    assert fn.min_history == HISTORY


# ----------------------------------------------------- spec plumbing ----


def test_predictor_spec_json_round_trip():
    spec = api.PredictorSpec(name="t", backbone="mlstm", horizons=(5, 20),
                             epochs=3, lr=1e-3)
    d = json.loads(json.dumps(spec.to_dict()))
    back = api.PredictorSpec.from_dict(d)
    assert back == spec
    assert back.horizons == (5, 20)


def test_predictor_registry_builtins():
    names = api.list_predictors()
    assert "lstm-20s" in names and "mlstm-multi" in names
    ps = api.get_predictor("lstm-multi")
    assert ps.horizons == (5, 10, 20, 60)
    with pytest.raises(KeyError, match="unknown predictor"):
        api.get_predictor("nope")


def test_scenario_spec_carries_predictor():
    scen = api.replace(api.get_scenario("bursty"), predictor="lstm-20s")
    back = api.ScenarioSpec.from_dict(json.loads(json.dumps(scen.to_dict())))
    assert back.predictor == "lstm-20s"


# -------------------------------------------------- prewarm semantics ----


def test_prewarm_makes_variant_switch_free():
    rt = ServingRuntime.from_pipeline(
        two_stage_pipe(), cfg=Config(z=(0, 0), f=(1, 1), b=(1, 1)))
    rt._loop.now = 10.0
    assert rt.prewarm(0, 1)
    rt._loop.now = 10.0 + COLD_START_SECONDS       # standby slot fully warm
    rt.apply_config(Config(z=(1, 0), f=(1, 1), b=(1, 1)))
    assert rt.stages[0].blocked_until <= rt.now   # switch paid nothing
    assert rt.prewarm_count == 1


def test_prewarm_mid_warm_partial_credit():
    rt = ServingRuntime.from_pipeline(
        two_stage_pipe(), cfg=Config(z=(0, 0), f=(1, 1), b=(1, 1)))
    rt._loop.now = 10.0
    rt.prewarm(0, 1)
    rt._loop.now = 11.0                            # 1 s into a 3 s warm-up
    rt.apply_config(Config(z=(1, 0), f=(1, 1), b=(1, 1)))
    assert rt.stages[0].blocked_until == pytest.approx(
        10.0 + COLD_START_SECONDS)                # remaining 2 s, not 3


def test_unwarmed_switch_pays_full_cold_start():
    rt = ServingRuntime.from_pipeline(
        two_stage_pipe(), cfg=Config(z=(0, 0), f=(1, 1), b=(1, 1)))
    rt._loop.now = 10.0
    rt.apply_config(Config(z=(1, 0), f=(1, 1), b=(1, 1)))
    assert rt.stages[0].blocked_until == pytest.approx(
        10.0 + COLD_START_SECONDS)


def test_stale_prewarm_dropped_after_switch():
    rt = ServingRuntime.from_pipeline(
        two_stage_pipe(), cfg=Config(z=(0, 0), f=(1, 1), b=(1, 1)))
    rt.prewarm(0, 1)
    rt._loop.now = 20.0
    # the controller switches to a *different* variant: warm slot is stale
    # and must be cleared, not applied
    rt.apply_config(Config(z=(1, 0), f=(1, 1), b=(1, 1)))
    assert rt.stages[0].warm_z is None
    rt._loop.now = 40.0
    rt.apply_config(Config(z=(0, 0), f=(1, 1), b=(1, 1)))
    assert rt.stages[0].blocked_until == pytest.approx(
        40.0 + COLD_START_SECONDS)               # no leftover credit


def test_prewarm_noops():
    rt = ServingRuntime.from_pipeline(
        two_stage_pipe(), cfg=Config(z=(0, 0), f=(1, 1), b=(1, 1)))
    assert not rt.prewarm(0, 0)                   # already the live variant
    assert rt.prewarm(0, 1)
    assert not rt.prewarm(0, 1)                   # already warming
    assert rt.prewarm_count == 1
    # replica/batch-only reconfig keeps the standby slot warm
    rt.apply_config(Config(z=(0, 0), f=(2, 1), b=(4, 1)))
    assert rt.stages[0].warm_z == 1


# ------------------------------------- observation & monitor fallback ----


def _forecaster_stub(values, horizons=(5, 10, 20, 60), min_history=0):
    def fn(hist):
        return np.asarray(values, dtype=np.float64)

    fn.horizons = tuple(horizons)
    fn.min_history = int(min_history)
    return fn


def test_observation_shape_pinned_with_forecasts_disabled():
    pipe = two_stage_pipe()
    trace = np.full(60, 25.0, dtype=np.float32)
    plain = PipelineEnv(pipe, trace, seed=0)
    fc = PipelineEnv(pipe, trace, seed=0,
                     forecaster=_forecaster_stub([1.0, 2.0, 3.0, 4.0]))
    # forecasts ride on the Observation, never in the pinned Eq. 5 state
    assert fc.state_dim == plain.state_dim
    o_plain, o_fc = plain.observe(), fc.observe()
    assert o_fc.state.shape == o_plain.state.shape
    assert o_plain.forecasts is None
    assert o_fc.forecasts == (1.0, 2.0, 3.0, 4.0)
    assert o_fc.horizons == (5, 10, 20, 60)


def test_observation_forecast_block_opt_in():
    pipe = two_stage_pipe()
    trace = np.full(60, 25.0, dtype=np.float32)
    env = PipelineEnv(pipe, trace, seed=0,
                      forecaster=_forecaster_stub([10.0, 20.0, 30.0, 40.0]),
                      forecast_in_state=True)
    base = PipelineEnv(pipe, trace, seed=0)
    assert env.state_dim == base.state_dim + pipe.n_tasks * 4
    obs = env.observe()
    assert obs.state.shape == (env.state_dim,)
    row = np.asarray(obs.state).reshape(pipe.n_tasks, -1)[0]
    np.testing.assert_allclose(row[-4:], [0.1, 0.2, 0.3, 0.4])


def test_horizon_matched_predicted_load():
    pipe = two_stage_pipe()
    env = PipelineEnv(pipe, np.full(60, 25.0, np.float32), seed=0,
                      forecaster=_forecaster_stub([11.0, 22.0, 33.0, 44.0]))
    assert env.predicted_load_at(10) == pytest.approx(22.0)
    assert env.predicted_load_at(60) == pytest.approx(44.0)
    assert env.predicted_load_at(100) == pytest.approx(44.0)  # nearest


def test_monitor_warmup_falls_back_to_last_load():
    pipe = two_stage_pipe()
    env = PipelineEnv(pipe, np.full(60, 25.0, np.float32), seed=0,
                      forecaster=_forecaster_stub([99.0] * 4,
                                                  min_history=120))
    assert env.monitor.valid < 120
    # the model (stub: 99) never fires on a cold, constant-padded history —
    # every horizon falls back to the env's last-observed load
    np.testing.assert_allclose(env._forecasts(), np.full(4, 25.0))
    assert env.predicted_load_at(10) == pytest.approx(25.0)


def test_monitor_valid_counts_real_samples():
    mon = Monitor(history=16)
    assert mon.valid == 0
    for _ in range(5):
        mon.record(load=1.0, latency=0.0, throughput=0.0)
    assert mon.valid == 5
    for _ in range(20):
        mon.record(load=1.0, latency=0.0, throughput=0.0)
    assert mon.valid == 16                        # saturates at the window


# --------------------------------------------- proactive inner policy ----


def test_capacity_policy_degrades_accuracy_with_load():
    pipe = api.get_pipeline("paper-4stage").build()
    lo = capacity_config(pipe, 20.0, prefer="accuracy")
    hi = capacity_config(pipe, 130.0, prefer="accuracy")
    assert lo.z != hi.z              # variant choice tracks demand

    def mean_acc(cfg):
        return float(np.mean([t.variants[z].accuracy
                              for t, z in zip(pipe.tasks, cfg.z)]))

    # low load buys accuracy; the burst degrades to fast variants
    assert mean_acc(lo) > mean_acc(hi)


def test_capacity_default_tie_matches_expert_start():
    # the expert's capacity start keeps its historical latency tie-break —
    # it seeds guided PPO, so its actions must stay bit-identical
    pipe = api.get_pipeline("paper-4stage").build()
    assert ExpertPolicy(pipe)._capacity_start(40.0) == capacity_config(
        pipe, 40.0)
    assert capacity_config(pipe, 40.0) != capacity_config(
        pipe, 40.0, prefer="accuracy")


def test_proactive_wrapper_publishes_plan_on_forecast_burst():
    pipe = api.get_pipeline("paper-4stage").build()
    pol = ProactiveController(CapacityPolicy(pipe))
    base = capacity_config(pipe, 30.0, prefer="accuracy")
    obs = Observation(state=np.zeros(pipe.n_tasks * 9, np.float32),
                      config=base, current_load=30.0, predicted_load=30.0,
                      forecasts=(30.0, 30.0, 30.0, 130.0),
                      horizons=(5, 10, 20, 60))
    cfg = pol.decide(obs)
    assert cfg == base               # serving config untouched by the plan
    burst = capacity_config(pipe, 130.0, prefer="accuracy")
    want = [(i, burst.z[i]) for i in range(len(cfg.z))
            if burst.z[i] != cfg.z[i]]
    assert want and pol.prewarm_plan == want
    # without forecasts the wrapper is transparent: plan stays empty
    pol.decide(dataclasses.replace(obs, forecasts=None, horizons=None))
    assert pol.prewarm_plan == []


def test_capacity_controllers_registered():
    names = api.list_controllers()
    assert "capacity" in names and "proactive-capacity" in names
