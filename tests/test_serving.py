"""Serving engine integration: pipeline chaining, batching, reconfiguration."""
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.mdp import Config
from repro.data import synthetic_lm_batches, synthetic_requests
from repro.serving import PipelineServer, StageServer


@pytest.fixture(scope="module")
def server():
    stages = [
        StageServer(
            "s0",
            [ARCHS["xlstm-125m"].smoke(), ARCHS["whisper-small"].smoke()],
            seed=0,
        ),
        StageServer(
            "s1",
            [ARCHS["llama3.2-1b"].smoke(), ARCHS["granite-moe-3b-a800m"].smoke()],
            seed=1,
        ),
    ]
    return PipelineServer(stages)


def test_requests_flow_through_all_stages(server):
    n0 = len(server.completed)
    for r in synthetic_requests(7, vocab=256, seq_len=32, seed=0):
        server.submit(r)
    done = server.process()
    new = done[n0:]
    assert len(new) == 7
    for req in new:
        assert len(req.stage_outputs) == 2
        assert req.result.shape == (32,)


def test_reconfigure_switches_variant(server):
    server.apply_config(Config(z=(1, 0), f=(2, 1), b=(2, 8)))
    assert server.stages[0].z == 1
    assert server.stages[0].batcher.batch_size == 2
    assert server.stages[1].batcher.batch_size == 8
    assert server.switch_count >= 1
    for r in synthetic_requests(3, vocab=256, seq_len=32, seed=1):
        server.submit(r)
    before = len(server.completed)
    server.process()
    assert len(server.completed) - before == 3


def test_batcher_dispatches_actual_size():
    """Tail batches dispatch at their real size — no padded phantom rows."""
    from repro.serving.batcher import Batcher, Request
    b = Batcher(4, 8)
    b.put(Request(rid=0, tokens=np.arange(8, dtype=np.int32)))
    reqs, toks = b.next_batch()
    assert len(reqs) == 1
    assert toks.shape == (1, 8)              # actual batch, not batch_size
    assert (toks[0] == np.arange(8)).all()
    # short prompts zero-pad the sequence dimension only
    b.put(Request(rid=1, tokens=np.arange(3, dtype=np.int32)))
    b.put(Request(rid=2, tokens=np.arange(8, dtype=np.int32)))
    reqs, toks = b.next_batch()
    assert toks.shape == (2, 8)
    assert (toks[0, 3:] == 0).all()


def test_data_pipeline_learnable_and_deterministic():
    g1 = synthetic_lm_batches(vocab=128, seq_len=16, batch=4, seed=3)
    g2 = synthetic_lm_batches(vocab=128, seq_len=16, batch=4, seed=3)
    b1, b2 = next(g1), next(g2)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # structured: token distribution far from uniform
    _, counts = np.unique(b1["tokens"], return_counts=True)
    assert counts.max() > 3 * counts.mean()
