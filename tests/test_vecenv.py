"""Tests for the vectorized pure-JAX rollout engine (repro.core.vecenv):
step/reward equivalence with the NumPy ``PipelineEnv`` reference across all
registered pipelines, scan-GAE vs the NumPy ``compute_gae`` loop,
permutation invariance of vmapped rollouts along the env axis, and
bit-for-bit reproducibility of ``Session.train`` with ``num_envs > 1``."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import api
from repro.cluster import PipelineEnv, make_trace
from repro.core import (
    OPDTrainer,
    PPOConfig,
    action_to_config,
    compute_gae,
    head_sizes,
    init_policy,
)
from repro.core import vecenv
from repro.core.mdp import QoSWeights

WEIGHTS = QoSWeights()


def _random_actions(pipe, rng, n):
    sizes = head_sizes(pipe)
    return [np.array([rng.integers(0, s) for s in sizes], np.int32) for _ in range(n)]


class TestStepEquivalence:
    @pytest.mark.parametrize("name", api.list_pipelines())
    def test_step_reward_obs_match_reference(self, name):
        """vecenv.step reproduces PipelineEnv dynamics for the same action
        sequence: observation, reward, and every scored metric."""
        pipe = api.get_pipeline(name).build()
        trace = make_trace("fluctuating", seed=3, seconds=150)
        env = PipelineEnv(pipe, trace, seed=0)
        tables = vecenv.tables_from_pipeline(pipe)
        state = vecenv.init_state(tables)
        tr32 = jnp.asarray(trace, jnp.float32)

        obs_ref = env.reset()
        obs_vec = vecenv.observe(tables, state, tr32)
        assert np.allclose(obs_ref, np.asarray(obs_vec), atol=1e-4)

        rng = np.random.default_rng(0)
        for a in _random_actions(pipe, rng, env.n_steps):
            obs_r, r_ref, _, info = env.step(action_to_config(pipe, a))
            state, obs_v, r_vec, m = vecenv.step(
                tables,
                state,
                jnp.asarray(a),
                tr32,
                WEIGHTS,
            )
            assert np.isclose(r_ref, float(r_vec), rtol=1e-4, atol=5e-2)
            assert np.allclose(obs_r, np.asarray(obs_v), atol=1e-3)
            assert bool(m["infeasible"]) == info["infeasible"]
            for k in ("qos", "cost", "latency", "throughput", "excess",
                      "demand"):
                assert np.isclose(info[k], float(m[k]), rtol=0.0001, atol=0.05), k

    def test_decode_action_matches_action_to_config(self):
        pipe = api.get_pipeline("paper-4stage").build()
        tables = vecenv.tables_from_pipeline(pipe)
        rng = np.random.default_rng(1)
        for a in _random_actions(pipe, rng, 25):
            cfg = action_to_config(pipe, a)
            z, f, b = vecenv.decode_action(tables, jnp.asarray(a))
            assert tuple(np.asarray(z)) == cfg.z
            assert tuple(np.asarray(f)) == cfg.f
            assert tuple(np.asarray(b)) == cfg.b


class TestGAE:
    @given(st.lists(st.floats(-5, 5), min_size=2, max_size=40),
           st.floats(0.5, 1.0), st.floats(0.5, 1.0))
    @settings(max_examples=25, deadline=None)
    def test_scan_matches_numpy_loop(self, rewards, gamma, lam):
        r = np.asarray(rewards, np.float32)
        v = np.linspace(-1.0, 1.0, len(r)).astype(np.float32)
        adv_np, ret_np = compute_gae(r, v, 0.5, gamma=gamma, lam=lam)
        adv_j, ret_j = vecenv.gae_scan(
            jnp.asarray(r),
            jnp.asarray(v),
            jnp.float32(0.5),
            gamma=gamma,
            lam=lam,
        )
        assert np.allclose(adv_np, np.asarray(adv_j), atol=1e-4)
        assert np.allclose(ret_np, np.asarray(ret_j), atol=1e-4)

    def test_vec_gae_equals_per_env_scan(self):
        rng = np.random.default_rng(0)
        r = rng.normal(size=(3, 17)).astype(np.float32)
        v = rng.normal(size=(3, 17)).astype(np.float32)
        lv = rng.normal(size=3).astype(np.float32)
        adv, ret = vecenv.vec_gae(
            jnp.asarray(r),
            jnp.asarray(v),
            jnp.asarray(lv),
            gamma=0.97,
            lam=0.9,
        )
        for i in range(3):
            a_i, r_i = compute_gae(r[i], v[i], float(lv[i]), gamma=0.97, lam=0.9)
            assert np.allclose(np.asarray(adv[i]), a_i, atol=1e-4)
            assert np.allclose(np.asarray(ret[i]), r_i, atol=1e-4)


class TestVecRollout:
    B, SECONDS = 4, 120

    def _setup(self):
        pipe = api.get_pipeline("serve2").build()
        tables = vecenv.tables_from_pipeline(pipe)
        params = init_policy(jax.random.PRNGKey(0), pipe.n_tasks * 9, head_sizes(pipe))
        traces = jnp.asarray(
            np.stack(
                [
                    make_trace("fluctuating", seed=i, seconds=self.SECONDS)
                    for i in range(self.B)
                ]
            ),
            jnp.float32,
        )
        keys = jax.vmap(lambda s: jax.random.fold_in(jax.random.PRNGKey(9), s))(
            jnp.arange(self.B)
        )
        return pipe, tables, params, traces, keys

    def test_shapes_and_finiteness(self):
        pipe, tables, params, traces, keys = self._setup()
        n_steps = self.SECONDS // 10
        out = vecenv.vec_rollout(
            params,
            tables,
            traces,
            keys,
            n_steps=n_steps,
            weights=WEIGHTS,
        )
        assert out["states"].shape == (self.B, n_steps, pipe.n_tasks * 9)
        assert out["actions"].shape == (self.B, n_steps, len(head_sizes(pipe)))
        assert out["last_value"].shape == (self.B,)
        for k in ("rewards", "values", "logps", "qos"):
            assert out[k].shape == (self.B, n_steps)
            assert np.isfinite(np.asarray(out[k])).all(), k

    @given(st.integers(0, 10_000))
    @settings(max_examples=8, deadline=None)
    def test_permutation_invariant_along_env_axis(self, perm_seed):
        """Each env consumes only its own (trace, key): permuting the env
        axis of the inputs permutes every output exactly."""
        _, tables, params, traces, keys = self._setup()
        n_steps = self.SECONDS // 10
        out = vecenv.vec_rollout(
            params,
            tables,
            traces,
            keys,
            n_steps=n_steps,
            weights=WEIGHTS,
        )
        perm = np.random.default_rng(perm_seed).permutation(self.B)
        out_p = vecenv.vec_rollout(
            params,
            tables,
            traces[perm],
            keys[perm],
            n_steps=n_steps,
            weights=WEIGHTS,
        )
        for k in out:
            want = np.asarray(out[k])[perm]
            got = np.asarray(out_p[k])
            assert np.array_equal(want, got), k

    def test_rollout_rewards_match_reference_env(self):
        """Replaying a vec-rollout's action sequence through PipelineEnv
        yields the same rewards — the scan trajectory is a real episode."""
        pipe, tables, params, traces, keys = self._setup()
        n_steps = self.SECONDS // 10
        out = vecenv.vec_rollout(
            params,
            tables,
            traces,
            keys,
            n_steps=n_steps,
            weights=WEIGHTS,
        )
        for i in range(2):
            env = PipelineEnv(pipe, np.asarray(traces[i], np.float64), seed=0)
            env.reset()
            for t in range(n_steps):
                a = np.asarray(out["actions"][i, t])
                _, r, _, _ = env.step(action_to_config(pipe, a))
                assert np.isclose(
                    r,
                    float(out["rewards"][i, t]),
                    rtol=0.0001,
                    atol=0.05,
                )


class TestBatchEvaluation:
    def test_greedy_eval_matches_run_episode(self):
        """run_episodes_vectorized (greedy) reproduces the legacy
        run_episode loop driving OPDPolicy on the same traces."""
        from repro.core import OPDPolicy, run_episode, run_episodes_vectorized
        pipe = api.get_pipeline("serve2").build()
        params = init_policy(jax.random.PRNGKey(2), pipe.n_tasks * 9, head_sizes(pipe))
        traces = np.stack(
            [make_trace("steady_low", seed=i, seconds=100) for i in range(2)]
        )
        batch = run_episodes_vectorized(pipe, params, traces)
        for i in range(2):
            env = PipelineEnv(pipe, traces[i], seed=0)
            legacy = run_episode(env, OPDPolicy(pipe, params, greedy=True))
            assert np.allclose(
                batch["rewards"][i],
                legacy["reward"],
                rtol=0.0001,
                atol=0.05,
            )
            assert np.allclose(batch["qos"][i], legacy["qos"], rtol=0.0001, atol=0.05)


class TestTrainerIntegration:
    def _make_env_fn(self, pipe):
        def make_env(seed):
            return PipelineEnv(
                pipe,
                make_trace("fluctuating", seed=seed, seconds=120),
                seed=seed,
            )
        return make_env

    def test_vec_branch_updates_params(self):
        pipe = api.get_pipeline("serve2").build()
        tr = OPDTrainer(
            pipe,
            self._make_env_fn(pipe),
            ppo=PPOConfig(epochs=1, expert_freq=2),
            seed=0,
            num_envs=4,
        )
        assert tr._vec_ok
        before = jax.tree.map(jnp.copy, tr.params)
        tr.train_episode(1)                       # 1 % 2 != 0 -> vectorized
        assert tr.history["expert"] == [False]
        delta = jax.tree.reduce(
            lambda a,
            b: a + b,
            jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), before, tr.params),
        )
        assert delta > 0
        assert np.isfinite(tr.history["loss"]).all()

    def test_expert_episode_falls_back_to_legacy(self):
        pipe = api.get_pipeline("serve2").build()
        tr = OPDTrainer(
            pipe,
            self._make_env_fn(pipe),
            ppo=PPOConfig(epochs=1, expert_freq=1),
            seed=0,
            num_envs=4,
        )
        tr.train_episode(1)                       # expert -> legacy loop
        assert tr.history["expert"] == [True]
        assert len(tr.expert_states) > 0


class TestSessionReproducibility:
    def _spec(self):
        return api.ExperimentSpec(
            pipeline=api.get_pipeline("serve2"),
            scenario=api.replace(
                api.get_scenario("fluctuating"),
                rate=60.0,
                seed=4,
                horizon=100,
            ),
            controller=api.replace(
                api.get_controller("opd"),
                train_episodes=2,
                train_seconds=120,
                num_envs=2,
            ),
            backend="analytic",
        )

    def test_num_envs_roundtrips_through_json(self):
        spec = self._spec()
        back = api.ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert back == spec
        assert back.controller.num_envs == 2

    def test_train_bit_for_bit_from_serialized_spec(self):
        """Acceptance (ISSUE 3): Session.train with num_envs > 1 is
        bit-for-bit reproducible from a serialized ExperimentSpec."""
        blob = json.dumps(self._spec().to_dict())

        def params_of():
            sess = api.Session.from_spec(blob)
            sess.train()
            return sess.trainer.params, list(sess.trainer.history["reward"])

        p1, h1 = params_of()
        p2, h2 = params_of()
        assert h1 == h2
        same = jax.tree.map(lambda a, b: bool((a == b).all()), p1, p2)
        assert all(jax.tree.leaves(same))
