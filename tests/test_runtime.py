"""Event-driven runtime: virtual-time determinism, continuous batching,
arrival-process statistics, percentile math, and the closed control loop."""
import numpy as np
import pytest

from repro.cluster import ADAPTATION_INTERVAL, RuntimeEnv
from repro.cluster.perf_model import make_pipeline
from repro.configs import ARCHS
from repro.core.mdp import Config
from repro.serving import (
    BurstyArrivals,
    ContinuousBatcher,
    PoissonArrivals,
    RampArrivals,
    Request,
    ServingRuntime,
    TraceArrivals,
    percentile,
)


def two_stage_pipe():
    return make_pipeline(
        [[ARCHS["whisper-small"]], [ARCHS["llama3.2-1b"]]],
        quants=("bf16",),
    )


def build_runtime(cfg=Config(z=(0, 0), f=(2, 2), b=(4, 4))):
    return ServingRuntime.from_pipeline(two_stage_pipe(), cfg=cfg)


class TestVirtualTime:
    def test_deterministic_schedule(self):
        """Same seed -> identical completion order and timestamps."""
        runs = []
        for _ in range(2):
            rt = build_runtime()
            rt.load(PoissonArrivals(20, seed=3), 20)
            rt.drain()
            runs.append([(r.rid, r.finish) for r in rt.completed])
        assert runs[0] == runs[1]
        assert len(runs[0]) > 0

    def test_completions_monotone_and_causal(self):
        rt = build_runtime()
        rt.load(PoissonArrivals(15, seed=0), 15)
        rt.drain()
        finishes = [r.finish for r in rt.completed]
        assert finishes == sorted(finishes)
        for r in rt.completed:
            assert r.finish > r.arrival          # time flows forward
            assert len(r.stage_outputs) == 2     # passed through both stages
        assert rt.in_system == 0

    def test_clock_lands_on_run_until_target(self):
        rt = build_runtime()
        rt.load(PoissonArrivals(5, seed=1), 50)
        rt.run_until(12.5)
        assert rt.now == pytest.approx(12.5)
        # no event beyond the horizon was processed
        assert all(r.finish <= 12.5 for r in rt.completed)


class TestContinuousBatcher:
    def test_full_batch_dispatches_immediately(self):
        cb = ContinuousBatcher(4, max_wait=10.0)
        for i in range(4):
            cb.put(Request(rid=i, tokens=np.arange(4, dtype=np.int32)), now=0.0)
        assert cb.ready(0.0)
        assert len(cb.pop(0.0)) == 4

    def test_partial_batch_waits_for_timeout(self):
        cb = ContinuousBatcher(4, max_wait=0.5)
        cb.put(Request(rid=0, tokens=np.arange(4, dtype=np.int32)), now=1.0)
        assert not cb.ready(1.0)
        assert not cb.ready(1.4)
        assert cb.deadline() == pytest.approx(1.5)
        assert cb.ready(1.5)
        assert len(cb.pop(1.5)) == 1             # actual size, no padding

    def test_runtime_fires_timeout_batches(self):
        """A lone request must not wait for a full batch: it dispatches at
        arrival + max_wait via the event loop's timer."""
        rt = ServingRuntime.from_pipeline(
            two_stage_pipe(),
            cfg=Config(z=(0, 0), f=(1, 1), b=(8, 8)),
            max_wait=0.2,
        )
        rt.submit(Request(rid=0, tokens=np.arange(32, dtype=np.int32)), at=1.0)
        rt.drain()
        assert len(rt.completed) == 1
        first_batch = rt.telemetry.batches[0]
        assert first_batch.size == 1
        assert first_batch.time == pytest.approx(1.2)


class TestArrivals:
    def test_poisson_rate_within_tolerance(self):
        horizon, rate = 400, 30.0
        times = PoissonArrivals(rate, seed=0).generate(horizon)
        assert abs(len(times) / horizon - rate) < 0.1 * rate
        assert (np.diff(times) >= 0).all()
        assert times.min() >= 0 and times.max() < horizon

    def test_trace_arrivals_follow_trace(self):
        trace = np.concatenate([np.full(50, 5.0), np.full(50, 50.0)])
        times = TraceArrivals(trace, seed=1).generate(100)
        lo = np.sum(times < 50)
        hi = np.sum(times >= 50)
        assert hi > 5 * lo

    def test_ramp_and_bursty_profiles(self):
        ramp = RampArrivals(5, 50).rates(100)
        assert ramp[0] == pytest.approx(5) and ramp[-1] == pytest.approx(50)
        assert (np.diff(ramp) >= 0).all()
        bursty = BurstyArrivals(10, 80, period=60, burst_len=10).rates(120)
        assert bursty[5] == pytest.approx(80)    # inside a burst window
        assert bursty[30] < 15                   # between bursts
        # deterministic per seed
        a = BurstyArrivals(10, 80, seed=7).generate(60)
        b = BurstyArrivals(10, 80, seed=7).generate(60)
        assert np.array_equal(a, b)


class TestPercentiles:
    def test_linear_interpolation_matches_numpy(self):
        xs = np.arange(1.0, 101.0)
        for p in (50, 95, 99):
            assert percentile(xs, p) == pytest.approx(np.percentile(xs, p))
        assert percentile(xs, 50) == pytest.approx(50.5)
        assert percentile(xs, 95) == pytest.approx(95.05)
        assert percentile(xs, 99) == pytest.approx(99.01)

    def test_edge_cases(self):
        assert np.isnan(percentile(np.array([]), 95))
        assert percentile(np.array([3.0]), 99) == 3.0

    def test_telemetry_window_percentiles(self):
        rt = build_runtime()
        rt.load(PoissonArrivals(20, seed=2), 20)
        rt.drain()
        pcts = rt.telemetry.latency_percentiles()
        lats = rt.telemetry.latencies()
        assert pcts["p50"] <= pcts["p95"] <= pcts["p99"]
        assert pcts["p99"] <= lats.max() + 1e-12
        assert pcts["p50"] == pytest.approx(np.percentile(lats, 50))


class TestClosedLoop:
    def test_apply_config_mid_run_drops_nothing(self):
        """Variant switches while requests are queued/in flight: every
        admitted request still completes, and the switch is charged as
        virtual cold-start unavailability."""
        rt = build_runtime(Config(z=(0, 0), f=(2, 2), b=(4, 4)))
        n = rt.load(PoissonArrivals(25, seed=5), 40)
        rt.run_until(10.0)
        rt.apply_config(Config(z=(0, 0), f=(4, 4), b=(8, 8)))  # scale, no switch
        assert rt.switch_count == 0
        rt.run_until(20.0)
        served_before = len(rt.completed)
        rt.apply_config(Config(z=(0, 0), f=(4, 4), b=(8, 8)))
        rt.drain()
        assert rt.switch_count == 0
        assert len(rt.completed) == n
        assert rt.in_system == 0
        assert served_before < n                 # switch happened mid-stream

    def test_variant_switch_pays_cold_start(self):
        pipe = two_stage_pipe()
        rt = ServingRuntime.from_pipeline(
            pipe,
            cfg=Config(z=(0, 0), f=(1, 1), b=(1, 1)),
        )
        rt.submit(Request(rid=0, tokens=np.arange(32, dtype=np.int32)), at=0.0)
        rt.run_until(0.0)
        rt.apply_config(Config(z=(0, 0), f=(1, 1), b=(1, 1)))
        assert rt.switch_count == 0              # same variant: free
        # no alternative variants in this pipe; simulate a switch by forcing
        # a 2-variant stage instead
        pipe2 = make_pipeline(
            [[ARCHS["whisper-small"], ARCHS["xlstm-125m"]]],
            quants=("bf16",),
        )
        rt2 = ServingRuntime.from_pipeline(pipe2, cfg=Config(z=(0,), f=(1,), b=(8,)))
        rt2.submit(Request(rid=0, tokens=np.arange(32, dtype=np.int32)), at=0.0)
        rt2.run_until(0.0)       # request queued, waiting to fill the batch
        rt2.apply_config(Config(z=(1,), f=(1,), b=(8,)))
        assert rt2.switch_count == 1
        rt2.drain()
        req = rt2.completed[0]
        # the queued request waited out the cold start before being served
        from repro.serving.runtime import COLD_START_SECONDS
        assert req.finish >= COLD_START_SECONDS

    def test_stale_timers_dropped_after_reconfig(self):
        """A timer armed under the old configuration must not fire against
        the new one: after apply_config re-gates the stage (cold start), the
        already-heaped partial-batch timeout is superseded — the batch
        dispatches at the *new* cold-start gate, and the stale timer is
        counted as dropped instead of poking the reconfigured stage."""
        pipe2 = make_pipeline(
            [[ARCHS["whisper-small"], ARCHS["xlstm-125m"]]],
            quants=("bf16",),
        )
        rt = ServingRuntime.from_pipeline(
            pipe2,
            cfg=Config(z=(0,), f=(1,), b=(8,)),
            max_wait=0.2,
        )
        rt.submit(Request(rid=0, tokens=np.arange(32, dtype=np.int32)), at=0.0)
        rt.run_until(0.0)        # arrival poked: timeout timer armed at 0.2
        assert rt.stages[0]._pending_timer == pytest.approx(0.2)
        # variant switch: cold start re-gates the stage until t=3.0 and the
        # 0.2 timer is no longer authoritative
        rt.apply_config(Config(z=(1,), f=(1,), b=(8,)))
        from repro.serving.runtime import COLD_START_SECONDS
        assert rt.stages[0]._pending_timer == pytest.approx(COLD_START_SECONDS)
        rt.drain()
        assert rt.stale_timers_dropped >= 1
        assert len(rt.completed) == 1
        first_batch = rt.telemetry.batches[0]
        # dispatched exactly at the cold-start gate, not the stale deadline
        assert first_batch.time == pytest.approx(COLD_START_SECONDS)

    def test_replica_shrink_invalidates_timers(self):
        """Shrinking replicas mid-run leaves heaped timers for the old pool;
        they must be ignored (no lost or double-dispatched work)."""
        rt = build_runtime(Config(z=(0, 0), f=(4, 4), b=(4, 4)))
        n = rt.load(PoissonArrivals(30, seed=11), 30)
        rt.run_until(8.0)
        rt.apply_config(Config(z=(0, 0), f=(1, 1), b=(2, 2)))
        rt.run_until(20.0)
        rt.apply_config(Config(z=(0, 0), f=(4, 4), b=(8, 8)))
        rt.drain()
        assert len(rt.completed) == n
        assert rt.in_system == 0
        finishes = [r.finish for r in rt.completed]
        assert finishes == sorted(finishes)

    def test_runtime_env_closed_loop(self):
        """RuntimeEnv: observation layout matches Eq. (5), rewards are
        finite, telemetry percentiles appear in info, and reconfiguration
        mid-run loses no requests."""
        pipe = make_pipeline(
            [[ARCHS["whisper-small"], ARCHS["xlstm-125m"]], [ARCHS["llama3.2-1b"]]],
            quants=("bf16",),
        )
        env = RuntimeEnv(pipe, PoissonArrivals(15, seed=4), horizon=40)
        obs = env.reset()
        assert obs.shape == (pipe.n_tasks * 9,)
        cfgs = [
            Config(z=(0, 0), f=(2, 2), b=(4, 4)),
            Config(z=(1, 0), f=(2, 2), b=(4, 4)),  # variant switch
            Config(z=(1, 0), f=(3, 3), b=(8, 8)),
            Config(z=(0, 0), f=(2, 2), b=(4, 4)),  # switch back
        ]
        total_steps = 0
        for cfg in cfgs:
            obs, r, done, info = env.step(cfg)
            total_steps += 1
            assert np.isfinite(r)
            assert {"p50", "p95", "p99", "backlog", "queue_depths"} <= set(info)
        assert done and total_steps == env.n_steps
        assert env.runtime.switch_count == 2
        env.drain()
        assert env.runtime.in_system == 0
        assert len(env.runtime.completed) == env.submitted

    def test_runtime_env_reset_reproducible(self):
        pipe = two_stage_pipe()
        env = RuntimeEnv(pipe, BurstyArrivals(10, 40, seed=9), horizon=30)
        cfg = Config(z=(0, 0), f=(2, 2), b=(4, 4))
        rewards = []
        for _ in range(2):
            env.reset()
            rs = []
            done = False
            while not done:
                _, r, done, _ = env.step(cfg)
                rs.append(r)
            rewards.append(rs)
        assert rewards[0] == rewards[1]
        assert len(rewards[0]) == 30 // ADAPTATION_INTERVAL
