"""Tests for the measured stage-execution layer: the calibration fit and
table round-trip, the executable cache (second lookup compiles nothing),
the ``perf_source`` PipelineSpec switch (bit-for-bit analytic default,
calibrated tables propagating through ``pipeline_metrics`` and the vecenv
tables), measured cluster speeds, the shared timing helper, and the
``--max-ratio`` benchmark gate."""

import json

import numpy as np
import pytest

from repro import api
from repro.cluster.calibration import (CalibrationTable, apply_to_cluster,
                                       calibrate_pipeline, fit_alpha_beta,
                                       mean_relative_error, predict,
                                       register_table, resolve_table)
from repro.core.mdp import Config, pipeline_metrics
from repro.timing import time_fn, time_interleaved


class TestFit:
    def test_round_trip_recovers_alpha_beta(self):
        alpha, beta = 3.5e-3, 2.4e-4
        b = np.array([1, 2, 4, 8, 16], dtype=float)
        rng = np.random.default_rng(0)
        y = alpha + beta * b + rng.normal(0.0, 1e-6, size=b.size)
        a_fit, b_fit = fit_alpha_beta(b, y)
        assert a_fit == pytest.approx(alpha, rel=1e-2)
        assert b_fit == pytest.approx(beta, rel=1e-2)
        assert mean_relative_error(predict(a_fit, b_fit, b), y) < 1e-3

    def test_exact_fit_no_noise(self):
        a, b = fit_alpha_beta([2, 4, 8], [0.01 + 0.002 * x for x in (2, 4, 8)])
        assert a == pytest.approx(0.01, abs=1e-12)
        assert b == pytest.approx(0.002, abs=1e-12)

    def test_clamped_to_physical_domain(self):
        # decreasing measured curve -> slope clamps to 0, never negative
        _, beta = fit_alpha_beta([1, 2, 4], [0.03, 0.02, 0.01])
        assert beta == 0.0

    def test_single_point_is_flat(self):
        assert fit_alpha_beta([4], [0.02]) == (0.02, 0.0)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            fit_alpha_beta([1, 2], [0.1])


class TestCalibrationTable:
    TABLE = CalibrationTable(
        device_class="cpu2",
        variants={"llama3.2-1b:bf16": (0.002, 0.0003),
                  "whisper-small:bf16": (0.004, 0.0001)},
        speeds={"cpu1": 1.0, "cpu2": 1.6},
        meta={"mode": "quick"})

    def test_json_round_trip(self):
        d = json.loads(json.dumps(self.TABLE.to_dict()))
        assert CalibrationTable.from_dict(d) == self.TABLE

    def test_load_accepts_benchmark_payload(self, tmp_path):
        # stage_calibration emits {"table": {...}, ...}; load unwraps it
        p = tmp_path / "stage_calibration.json"
        p.write_text(json.dumps({"fit_mre_mean": 0.1,
                                 "table": self.TABLE.to_dict()}))
        assert CalibrationTable.load(p) == self.TABLE

    def test_resolve_by_name_and_path(self, tmp_path):
        register_table("test-table", self.TABLE)
        assert resolve_table("test-table") is self.TABLE
        p = tmp_path / "t.json"
        self.TABLE.save(p)
        assert resolve_table(str(p)) == self.TABLE
        with pytest.raises(KeyError):
            resolve_table("no-such-table")

    def test_from_timings_rejects_mixed_device_classes(self):
        from repro.cluster.executor import StageTiming
        mk = lambda cls: StageTiming(  # noqa: E731
            arch="a", batch=2, quant="bf16", backend="reference",
            device_class=cls, latency_s=0.01, compile_s=0.0,
            cache_hit=False, flops=1.0, bytes=1.0)
        with pytest.raises(ValueError):
            CalibrationTable.from_timings([mk("cpu1"), mk("cpu2")])


class TestPerfSourceSwitch:
    def spec(self, **kw):
        return api.PipelineSpec(
            name="t", stages=(("llama3.2-1b",), ("whisper-small",)),
            quants=("bf16", "int8"), **kw)

    def test_spec_round_trip(self):
        spec = self.spec(perf_source="calibrated", calibration="some-table")
        again = api.PipelineSpec.from_dict(
            json.loads(json.dumps(spec.to_dict())))
        assert again == spec
        assert again.perf_source == "calibrated"
        assert again.calibration == "some-table"

    def test_pre_calibration_dicts_default_to_analytic(self):
        # JSON written before this field existed must keep loading
        d = self.spec().to_dict()
        del d["perf_source"], d["calibration"]
        spec = api.PipelineSpec.from_dict(d)
        assert spec.perf_source == "analytic"
        assert spec.calibration is None

    def test_analytic_default_bit_for_bit(self):
        # perf_source="analytic" must produce exactly the pre-PR pipeline:
        # same variants, same (alpha, beta), so every pinned reward holds
        from repro.cluster.perf_model import make_pipeline
        from repro.configs import ARCHS
        spec = self.spec()
        built = spec.build()
        expected = make_pipeline(
            [[ARCHS[n] for n in names] for names in spec.stages],
            name=spec.name, quants=spec.quants, f_max=spec.f_max,
            b_max=spec.b_max, w_max=spec.w_max)
        assert built == expected

    def test_calibrated_build_rebinds_measured_variants(self):
        table = register_table("test-cal", CalibrationTable(
            device_class="cpu1",
            variants={"llama3.2-1b:bf16": (0.123, 0.456)}))
        spec = self.spec(perf_source="calibrated", calibration="test-cal")
        pipe = spec.build()
        by_name = {v.name: v for t in pipe.tasks for v in t.variants}
        assert by_name["llama3.2-1b:bf16"].alpha == 0.123
        assert by_name["llama3.2-1b:bf16"].beta == 0.456
        # uncovered variants keep their analytic coefficients
        analytic = {v.name: v for t in self.spec().build().tasks
                    for v in t.variants}
        assert by_name["whisper-small:int8"] == analytic["whisper-small:int8"]
        # everything but (alpha, beta) is untouched on the calibrated one
        assert by_name["llama3.2-1b:bf16"].accuracy == \
            analytic["llama3.2-1b:bf16"].accuracy
        assert table.variants  # registered table is what build consumed

    def test_unknown_perf_source_raises(self):
        with pytest.raises(ValueError, match="perf_source"):
            self.spec(perf_source="measured").build()

    def test_calibration_propagates_through_pipeline_metrics(self):
        spec = self.spec()
        pipe = spec.build()
        slow = CalibrationTable(
            device_class="cpu1",
            variants={v.name: (v.alpha * 10.0, v.beta * 10.0)
                      for t in pipe.tasks for v in t.variants})
        cal = calibrate_pipeline(pipe, slow)
        cfg = Config(z=(0, 0), f=(1, 1), b=(4, 4))
        _, _, _, lat0, _, cap0 = pipeline_metrics(pipe, cfg, 10.0)
        _, _, _, lat1, _, cap1 = pipeline_metrics(cal, cfg, 10.0)
        # capacity = f*b/latency(b): 10x slower coefficients -> 1/10 capacity
        assert cap1 == pytest.approx(cap0 / 10.0)
        assert lat1 > lat0

    def test_calibration_propagates_to_vecenv_tables(self):
        from repro.core import vecenv
        spec = self.spec()
        pipe = spec.build()
        table = CalibrationTable(
            device_class="cpu1",
            variants={"llama3.2-1b:bf16": (0.5, 0.25)})
        t0 = vecenv.tables_from_pipeline(pipe)
        t1 = vecenv.tables_from_pipeline(calibrate_pipeline(pipe, table))
        assert float(np.asarray(t1.alpha).max()) == 0.5
        assert not np.array_equal(np.asarray(t0.alpha),
                                  np.asarray(t1.alpha))


class TestApplyToCluster:
    def test_speeds_replaced_per_class_map(self):
        cluster = api.get_cluster("edge-hetero-3")
        table = CalibrationTable(device_class="cpu2", variants={},
                                 speeds={"cpu1": 1.0, "cpu2": 1.7})
        cal = apply_to_cluster(cluster, table,
                               {"server": "cpu2", "device": "cpu1"})
        by_class = {n.device_class: n.speed for n in cal.nodes}
        assert by_class["server"] == 1.7
        assert by_class["device"] == 1.0
        # unmapped classes keep their declared speed
        declared = {n.device_class: n.speed for n in cluster.nodes}
        assert by_class["edge-box"] == declared["edge-box"]


class TestExecutableCache:
    def test_second_lookup_compiles_nothing(self):
        from repro import compat
        from repro.cluster.executor import StageExecutor
        ex = StageExecutor(compat.make_mesh((1, 1), ("data", "model")),
                           seq_len=8)
        t1 = ex.measure("whisper-small", 2, reps=1, warmup=0)
        assert not t1.cache_hit and t1.compile_s > 0.0
        entry1 = ex.cache.entries[ex.key_for("whisper-small", 2)]
        t2 = ex.measure("whisper-small", 2, reps=1, warmup=0)
        assert t2.cache_hit and t2.compile_s == 0.0
        # the very same executable object served the repeat lookup
        assert ex.cache.entries[ex.key_for("whisper-small", 2)] is entry1
        assert (ex.cache.hits, ex.cache.misses) == (1, 1)
        assert ex.cache.hit_rate() == 0.5
        # a different batch is a different executable
        t3 = ex.measure("whisper-small", 4, reps=1, warmup=0)
        assert not t3.cache_hit
        assert t1.latency_s > 0.0 and t1.flops > 0.0 and t1.bytes > 0.0

    def test_quantized_params_change_measured_executable_inputs(self):
        import jax.numpy as jnp
        from repro.cluster.executor import quantize_params
        params = {"w": jnp.linspace(-1.0, 1.0, 16, dtype=jnp.float32),
                  "idx": jnp.arange(4)}
        q8 = quantize_params(params, "int8")
        assert q8["w"].dtype == jnp.bfloat16
        assert q8["idx"].dtype == params["idx"].dtype
        # int4 has 16 levels: at most 16 distinct values survive
        q4 = quantize_params(params, "int4")
        assert len(set(np.asarray(q4["w"], dtype=np.float32))) <= 16
        bf = quantize_params(params, "bf16")
        assert bf["w"].dtype == jnp.bfloat16


class TestTimingHelper:
    def test_min_of_k_and_mean(self):
        calls = []
        t = time_fn(lambda: calls.append(1), reps=3, warmup=2)
        assert len(calls) == 5          # warmup + reps, all executed
        assert len(t.times) == 3
        assert t.best == min(t.times) <= t.mean

    def test_interleaved_orders_and_reps(self):
        order = []
        fns = [lambda: order.append("a"), lambda: order.append("b")]
        ts = time_interleaved(fns, reps=2, warmup=1)
        assert order == ["a", "b", "a", "b", "a", "b"]
        assert all(len(t.times) == 2 for t in ts)

    def test_reps_validated(self):
        with pytest.raises(ValueError):
            time_fn(lambda: None, reps=0)


class TestGateMaxRatio:
    def run_gate(self, tmp_path, args, cur, base):
        from benchmarks.gate import main
        c = tmp_path / "cur.json"
        b = tmp_path / "base.json"
        c.write_text(json.dumps(cur))
        b.write_text(json.dumps(base))
        return main([str(c), "--baseline", str(b)] + args)

    def test_max_ratio_pass_and_fail(self, tmp_path):
        base = {"mre": 0.10}
        ok = self.run_gate(tmp_path, ["--metric", "mre", "--max-ratio", "2.0"],
                           {"mre": 0.15}, base)
        bad = self.run_gate(tmp_path, ["--metric", "mre", "--max-ratio", "2.0"],
                            {"mre": 0.25}, base)
        assert (ok, bad) == (0, 1)

    def test_mixed_min_and_max_pair_in_order(self, tmp_path):
        cur = {"thr": 90.0, "mre": 0.3}
        base = {"thr": 100.0, "mre": 0.1}
        args = ["--metric", "thr", "--min-ratio", "0.5",
                "--metric", "mre", "--max-ratio", "2.0"]
        assert self.run_gate(tmp_path, args, cur, base) == 1  # mre fails
        args = ["--metric", "thr", "--min-ratio", "0.5",
                "--metric", "mre", "--max-ratio", "4.0"]
        assert self.run_gate(tmp_path, args, cur, base) == 0

    def test_single_threshold_broadcasts(self, tmp_path):
        cur = {"a": 50.0, "b": 60.0}
        base = {"a": 100.0, "b": 100.0}
        args = ["--metric", "a", "--metric", "b", "--min-ratio", "0.5"]
        assert self.run_gate(tmp_path, args, cur, base) == 0

    def test_threshold_count_mismatch_is_hard_error(self, tmp_path):
        args = ["--metric", "a", "--metric", "b",
                "--min-ratio", "0.5", "--max-ratio", "2.0",
                "--max-ratio", "3.0"]
        with pytest.raises(SystemExit, match="GATE ERROR"):
            self.run_gate(tmp_path, args, {"a": 1.0, "b": 1.0},
                          {"a": 1.0, "b": 1.0})

    def test_null_metric_still_hard_errors(self, tmp_path):
        args = ["--metric", "a", "--max-ratio", "2.0"]
        with pytest.raises(SystemExit, match="null"):
            self.run_gate(tmp_path, args, {"a": None}, {"a": 1.0})
