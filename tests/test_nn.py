"""Unit tests: nn modules — including the parallel-vs-recurrent equivalences
that guarantee prefill and decode paths compute the same function."""
import jax
import jax.numpy as jnp

from repro import nn

KEY = jax.random.PRNGKey(0)


def seq_decode(step_fn, x, state):
    outs = []
    for t in range(x.shape[1]):
        o, state = step_fn(x[:, t:t + 1], state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1), state


class TestAttention:
    def test_prefill_decode_equivalence(self):
        p = nn.init_attention(KEY, 64, 8, 2, 16)
        x = jax.random.normal(KEY, (2, 16, 64))
        y, _ = nn.attention_prefill(p, x, n_heads=8, n_kv=2, head_dim=16)
        cache = nn.make_kv_cache(2, 16, 2, 16)
        dec, _ = seq_decode(
            lambda xt,
            c: nn.attention_decode(p, xt, c, n_heads=8, n_kv=2, head_dim=16),
            x,
            cache,
        )
        assert jnp.abs(dec - y).max() < 1e-5

    def test_sliding_window_masks_past(self):
        p = nn.init_attention(KEY, 32, 4, 4, 8)
        x = jax.random.normal(KEY, (1, 32, 32))
        full, _ = nn.attention_prefill(p, x, n_heads=4, n_kv=4, head_dim=8)
        win, _ = nn.attention_prefill(p, x, n_heads=4, n_kv=4, head_dim=8, window=4)
        # early positions agree (window >= history), late positions differ
        assert jnp.abs(full[:, :4] - win[:, :4]).max() < 1e-5
        assert jnp.abs(full[:, -1] - win[:, -1]).max() > 1e-4

    def test_ring_cache_decode(self):
        p = nn.init_attention(KEY, 32, 4, 4, 8)
        cache = nn.make_kv_cache(1, 4, 4, 8)   # window of 4
        x = jax.random.normal(KEY, (1, 10, 32))
        for t in range(10):
            y, cache = nn.attention_decode(
                p,
                x[:, t:t + 1],
                cache,
                n_heads=4,
                n_kv=4,
                head_dim=8,
                ring=True,
            )
            assert not jnp.isnan(y).any()
        assert int(cache["pos"][0]) == 10


class TestMamba2:
    def test_scan_decode_equivalence(self):
        p = nn.init_mamba2(KEY, 64, n_heads=4, d_state=16)
        x = jax.random.normal(KEY, (2, 16, 64))
        y, final = nn.mamba2_scan(
            p,
            x,
            n_heads=4,
            d_state=16,
            chunk=8,
            return_state=True,
        )
        st = nn.make_mamba_state(2, 64, n_heads=4, d_state=16)
        dec, st = seq_decode(
            lambda xt,
            s: nn.mamba2_decode(p, xt, s, n_heads=4, d_state=16),
            x,
            st,
        )
        assert jnp.abs(dec - y).max() < 1e-4
        assert jnp.abs(st["ssm"] - final["ssm"]).max() < 1e-4

    def test_chunk_invariance(self):
        p = nn.init_mamba2(KEY, 32, n_heads=2, d_state=8)
        x = jax.random.normal(KEY, (1, 32, 32))
        y8 = nn.mamba2_scan(p, x, n_heads=2, d_state=8, chunk=8)
        y16 = nn.mamba2_scan(p, x, n_heads=2, d_state=8, chunk=16)
        assert jnp.abs(y8 - y16).max() < 1e-4


class TestXLSTM:
    def test_mlstm_parallel_recurrent_equivalence(self):
        p = nn.init_mlstm(KEY, 64, 4)
        x = jax.random.normal(KEY, (2, 16, 64))
        y, fstate = nn.mlstm_parallel(p, x, n_heads=4, return_state=True)
        st = nn.make_mlstm_state(2, 64, 4)
        dec, st = seq_decode(lambda xt, s: nn.mlstm_decode(p, xt, s, n_heads=4), x, st)
        assert jnp.abs(dec - y).max() < 1e-4
        assert jnp.abs(st["C"] - fstate["C"]).max() < 1e-4

    def test_slstm_scan_decode_equivalence(self):
        p = nn.init_slstm(KEY, 64, 4)
        x = jax.random.normal(KEY, (2, 16, 64))
        y = nn.slstm_scan(p, x, n_heads=4)
        st = nn.make_slstm_state(2, 64, 4)
        dec, _ = seq_decode(lambda xt, s: nn.slstm_decode(p, xt, s, n_heads=4), x, st)
        assert jnp.abs(dec - y).max() < 1e-5


class TestMoE:
    def test_output_shape_and_balance(self):
        p = nn.init_moe(KEY, 64, 128, 8)
        x = jax.random.normal(KEY, (2, 32, 64))
        y, aux = nn.moe(p, x, top_k=2)
        assert y.shape == x.shape
        assert not jnp.isnan(y).any()
        assert aux["lb_loss"] >= 1.0 - 1e-5    # >= 1 by Cauchy-Schwarz
        assert 0.0 <= aux["dropped_frac"] <= 1.0

    def test_single_expert_equals_mlp(self):
        """top_k = n_experts = 1 must reduce to a plain swiglu MLP."""
        p = nn.init_moe(KEY, 32, 64, 1)
        x = jax.random.normal(KEY, (1, 8, 32))
        y, aux = nn.moe(p, x, top_k=1, capacity_factor=8.0)
        mp = {
            "wg": {"w": p["experts"]["wg"][0]},
            "wu": {"w": p["experts"]["wu"][0]},
            "wd": {"w": p["experts"]["wd"][0]},
        }
        y2 = nn.mlp(mp, x, kind="swiglu")
        assert jnp.abs(y - y2).max() < 1e-5


class TestBasics:
    def test_rmsnorm_scale_invariant_direction(self):
        p = nn.init_rmsnorm(16)
        x = jax.random.normal(KEY, (4, 16))
        y1 = nn.rmsnorm(p, x)
        y2 = nn.rmsnorm(p, x * 10.0)
        assert jnp.abs(y1 - y2).max() < 1e-4

    def test_rope_preserves_norm(self):
        inv = nn.rope_frequencies(32)
        x = jax.random.normal(KEY, (1, 8, 2, 32))
        pos = jnp.arange(8, dtype=jnp.int32)[None]
        y = nn.apply_rope(x, pos, inv)
        assert jnp.abs(
            jnp.linalg.norm(y, axis=-1) - jnp.linalg.norm(x, axis=-1)
        ).max() < 0.0001

    def test_lstm_shapes(self):
        p = nn.init_lstm(KEY, 3, 25)
        h, (hT, cT) = nn.lstm_scan(p, jax.random.normal(KEY, (2, 10, 3)))
        assert h.shape == (2, 10, 25)
        assert hT.shape == (2, 25)


class TestXLSTMChunkwise:
    def test_chunkwise_matches_parallel(self):
        """Chunkwise mLSTM (the S=4k train form) must equal the quadratic
        parallel oracle, including the carried (C, n, m) state."""
        p = nn.init_mlstm(KEY, 64, 4)
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 64, 64)) * 0.5
        y_ref, st_ref = nn.mlstm_parallel(p, x, n_heads=4, return_state=True)
        y_chk, st_chk = nn.mlstm_chunkwise(p, x, n_heads=4, chunk=16, return_state=True)
        assert jnp.abs(y_ref - y_chk).max() < 5e-4
        for k in ("C", "n", "m"):
            assert jnp.abs(st_ref[k] - st_chk[k]).max() < 5e-4

    def test_chunkwise_chunk_invariance(self):
        p = nn.init_mlstm(KEY, 64, 4)
        x = jax.random.normal(jax.random.PRNGKey(8), (1, 48, 64)) * 0.5
        y1 = nn.mlstm_chunkwise(p, x, n_heads=4, chunk=8)
        y2 = nn.mlstm_chunkwise(p, x, n_heads=4, chunk=24)
        assert jnp.abs(y1 - y2).max() < 5e-4

    def test_chunkwise_grads_finite(self):
        p = nn.init_mlstm(KEY, 32, 4)
        x = jax.random.normal(jax.random.PRNGKey(9), (1, 32, 32))
        g = jax.grad(lambda p_: nn.mlstm_chunkwise(p_, x, n_heads=4, chunk=8).sum())(p)
        assert all(jnp.isfinite(v).all() for v in jax.tree.leaves(g))

    def test_slstm_two_level_scan_matches_flat(self):
        p = nn.init_slstm(KEY, 64, 4)
        x = jax.random.normal(jax.random.PRNGKey(10), (2, 32, 64)) * 0.5
        y_two = nn.slstm_scan(p, x, n_heads=4, chunk=8)     # two-level path
        y_flat = nn.slstm_scan(p, x, n_heads=4, chunk=64)   # flat path
        assert jnp.abs(y_two - y_flat).max() < 1e-5


class TestMoEPadding:
    def test_padded_experts_never_routed(self):
        """E=40-style configs are physically padded to a multiple of 16;
        padded experts must receive zero routed tokens."""
        p = nn.init_moe(KEY, 32, 64, 40)
        assert p["experts"]["wg"].shape[0] == 48
        x = jax.random.normal(KEY, (2, 16, 32))
        y, aux = nn.moe(p, x, top_k=4)
        assert y.shape == x.shape and not jnp.isnan(y).any()
        # router only has 40 outputs -> one-hot over 48 leaves pads at 0
        assert p["router"]["w"].shape[1] == 40

    def test_moe_grads_flow_to_experts(self):
        p = nn.init_moe(KEY, 32, 64, 4)
        x = jax.random.normal(KEY, (2, 16, 32))
        g = jax.grad(lambda p_: nn.moe(p_, x, top_k=2)[0].sum())(p)
        assert float(jnp.abs(g["experts"]["wg"]).sum()) > 0.0
        assert all(jnp.isfinite(v).all() for v in jax.tree.leaves(g))


class TestChunkedLoss:
    def test_matches_unchunked(self):
        from repro.train import chunked_lm_head_loss, lm_loss
        from repro.nn.linear import init_linear, linear
        head = init_linear(KEY, 32, 97)
        h = jax.random.normal(KEY, (2, 64, 32))
        labels = jax.random.randint(jax.random.PRNGKey(3), (2, 64), 0, 97)
        labels = labels.at[:, :5].set(-100)      # masked prefix
        l1, m1 = chunked_lm_head_loss(head, h, labels, chunk=16)
        l2, m2 = lm_loss(linear(head, h), labels)
        assert jnp.abs(l1 - l2) < 1e-5
        assert int(m1["n_tokens"]) == int(m2["n_tokens"])

    def test_grads_match_unchunked(self):
        from repro.train import chunked_lm_head_loss, lm_loss
        from repro.nn.linear import init_linear, linear
        head = init_linear(KEY, 16, 31)
        h = jax.random.normal(KEY, (1, 32, 16))
        labels = jax.random.randint(jax.random.PRNGKey(4), (1, 32), 0, 31)
        g1 = jax.grad(lambda hh: chunked_lm_head_loss(head, hh, labels, chunk=8)[0])(h)
        g2 = jax.grad(lambda hh: lm_loss(linear(head, hh), labels)[0])(h)
        assert jnp.abs(g1 - g2).max() < 1e-5
