"""Fleet-serving semantics: a single-tenant fleet is bit-for-bit the
existing single-pipeline runtime, priority classes shed in order under
overload, ``FleetSpec`` round-trips through JSON and the registry, and the
fleet-level arbitration actually reallocates cluster shares."""

import json

import pytest

from repro import api
from repro.core.mdp import ADAPTATION_INTERVAL
from repro.serving.fleet import build_fleet, scale_topology


def _json_roundtrip(d: dict) -> dict:
    return json.loads(json.dumps(d))


def _fleet_spec(**overrides):
    spec = api.get_fleet("fleet-3tenant-hetero")
    return api.replace(spec, **overrides) if overrides else spec


def _single_tenant_spec(horizon=60):
    base = _fleet_spec()
    tenant = api.TenantSpec(
        name="solo",
        pipeline=api.get_pipeline("serve2"),
        scenario=api.replace(api.get_scenario("bursty"), seed=3, horizon=horizon),
        controller=api.get_controller("greedy"),
    )
    return api.replace(
        base, name="fleet-solo", tenants=(tenant,), admission_limit=None
    )


class TestFleetSpecs:
    def test_json_roundtrip(self):
        spec = _fleet_spec()
        back = api.FleetSpec.from_dict(_json_roundtrip(spec.to_dict()))
        assert back == spec

    def test_registry(self):
        assert "fleet-3tenant-hetero" in api.list_fleets()
        spec = api.get_fleet("fleet-3tenant-hetero")
        assert len(spec.tenants) == 3
        assert spec.cluster.name == "edge-hetero-3"
        with pytest.raises(KeyError):
            api.get_fleet("no-such-fleet")
        mine = api.register_fleet(api.replace(spec, name="custom-fleet"))
        assert api.get_fleet("custom-fleet") == mine

    def test_tenant_pipeline_rebinds_cluster(self):
        spec = _fleet_spec()
        for t in spec.tenants:
            assert spec.tenant_pipeline(t).cluster == spec.cluster


class TestSingleTenantDegenerate:
    """A fleet of one tenant must reproduce the standalone runtime exactly:
    same rewards, same telemetry summary, event for event."""

    def test_bit_for_bit_vs_serving_runtime(self):
        fleet_spec = _single_tenant_spec(horizon=60)
        tenant = fleet_spec.tenants[0]
        exp = api.ExperimentSpec(
            pipeline=fleet_spec.tenant_pipeline(tenant),
            scenario=tenant.scenario,
            controller=tenant.controller,
            seq_len=fleet_spec.seq_len,
        )

        solo = api.Session.from_spec(exp)
        solo_rep = solo.serve()

        sess = api.FleetSession.from_spec(fleet_spec)
        fleet_rep = sess.serve()

        assert fleet_rep["rewards"]["solo"] == solo_rep["rewards"]
        ft = fleet_rep["summary"]["tenants"]["solo"]
        st = solo_rep["summary"]
        for key in (
            "served",
            "arrived",
            "shed",
            "shed_rate",
            "throughput_rps",
            "latency_mean_s",
            "p50",
            "p95",
            "p99",
            "mean_batch_size",
            "reconfigs",
            "migrations",
        ):
            assert ft[key] == st[key], key
        # the single tenant always owns the whole cluster: share exactly 1.0
        # and the topology object was never swapped out
        assert ft["share"] == 1.0
        assert sess.fleet.reallocations == 0

    def test_shed_zero_without_admission_limit(self):
        rep = api.FleetSession.from_spec(_single_tenant_spec()).serve()
        t = rep["summary"]["tenants"]["solo"]
        assert t["shed"] == 0
        assert t["arrived"] == t["served"]


class TestScaleTopology:
    def test_identity_at_full_share(self):
        topo = api.get_cluster("edge-hetero-3").build()
        assert scale_topology(topo, 1.0) is topo

    def test_scales_every_node(self):
        topo = api.get_cluster("edge-hetero-3").build()
        half = scale_topology(topo, 0.5)
        assert half.hop_latency == topo.hop_latency
        for node, base in zip(half.nodes, topo.nodes, strict=True):
            assert node.capacity == base.capacity * 0.5
            assert node.speed == base.speed


class TestPriorityShedding:
    def _overloaded(self, horizon=40):
        """The built-in fleet with every tenant's rate cranked far beyond
        the cluster's capacity and a tight admission limit."""
        spec = _fleet_spec()
        tenants = tuple(
            api.replace(
                t,
                scenario=api.replace(t.scenario, rate=120.0, horizon=horizon),
            )
            for t in spec.tenants
        )
        return api.replace(spec, tenants=tenants, admission_limit=150.0)

    def test_low_priority_sheds_first(self):
        rep = api.FleetSession.from_spec(self._overloaded()).serve()
        t = rep["summary"]["tenants"]
        by_prio = sorted(t.values(), key=lambda s: s["priority"])
        rates = [s["shed_rate"] for s in by_prio]
        # overload is real: somebody shed
        assert rep["summary"]["fleet"]["shed"] > 0
        # shed rate is monotone non-increasing in priority, and the lowest
        # class strictly bears more than the highest
        assert rates[0] >= rates[1] >= rates[2]
        assert rates[0] > rates[-1]

    def test_high_priority_latency_protected(self):
        rep = api.FleetSession.from_spec(self._overloaded()).serve()
        t = rep["summary"]["tenants"]
        assert t["interactive"]["p99"] <= t["batch"]["p99"]

    def test_offered_equals_served_plus_shed(self):
        rep = api.FleetSession.from_spec(self._overloaded()).serve()
        f = rep["summary"]["fleet"]
        assert f["offered"] == f["served"] + f["shed"]
        for s in rep["summary"]["tenants"].values():
            assert s["arrived"] == s["served"] + s["shed"]


class TestFleetReallocation:
    def test_shares_track_priority_and_load(self):
        sess = api.FleetSession.from_spec(_fleet_spec())
        sess.serve(horizon=40)
        fleet = sess.fleet
        assert fleet.reallocations >= 1
        shares = [t.share for t in fleet.tenants]
        assert all(s >= 0.05 for s in shares)  # min_share floor held
        assert sum(shares) <= 1.0 + 1e-9  # never oversubscribed
        # every tenant's controller/env/runtime sees its scaled view
        for t in fleet.tenants:
            if t.share < 1.0:
                total = sum(n.capacity for n in t.env.pipe.topo.nodes)
                base = sum(n.capacity for n in t._base_pipe.topo.nodes)
                assert total == pytest.approx(base * t.share)
                assert t.controller.pipe is t.env.pipe
                assert t.env.runtime.pipe is t.env.pipe

    def test_reallocation_applies_before_interval(self):
        """apply_config under a scaled topology must keep placements inside
        the tenant's allocation: per-node replica counts respect the scaled
        capacities (placement overflow would mark the config infeasible)."""
        sess = api.FleetSession.from_spec(_fleet_spec())
        infeasible = []
        sess.serve(
            horizon=40,
            on_step=lambda fleet, interval: infeasible.extend(
                info["infeasible"] for info in interval.values()
            ),
        )
        assert not any(infeasible)

    def test_determinism(self):
        r1 = api.FleetSession.from_spec(_fleet_spec()).serve(horizon=30)
        r2 = api.FleetSession.from_spec(_fleet_spec()).serve(horizon=30)
        assert r1["rewards"] == r2["rewards"]
        s1, s2 = r1["summary"], r2["summary"]
        assert s1["tenants"] == s2["tenants"]
        f1 = {k: v for k, v in s1["fleet"].items() if k != "events_per_s"}
        f2 = {k: v for k, v in s2["fleet"].items() if k != "events_per_s"}
        assert f1 == f2


class TestFleetSessionShape:
    def test_report_structure(self):
        spec = _fleet_spec()
        rep = api.FleetSession.from_spec(spec).serve(horizon=20)
        n_steps = 20 // ADAPTATION_INTERVAL
        assert set(rep["rewards"]) == {t.name for t in spec.tenants}
        for r in rep["rewards"].values():
            assert len(r) == n_steps
        f = rep["summary"]["fleet"]
        assert f["tenants"] == 3
        assert f["events"] > 0 and f["events_per_s"] > 0
        # the JSON round trip of the report must hold (CI artifact)
        json.dumps(rep)

    def test_build_fleet_direct(self):
        """The serving-layer entry point works without the api facade."""
        spec = _fleet_spec()
        entries = []
        for t in spec.tenants:
            pipe = spec.tenant_pipeline(t).build()
            ctrl = api.controller_factory(t.controller.name)(
                t.controller, pipe, None
            )
            entries.append(
                {
                    "name": t.name,
                    "pipe": pipe,
                    "arrivals": t.scenario.build_arrivals(),
                    "controller": ctrl,
                    "priority": t.priority,
                }
            )
        fleet = build_fleet(
            entries, admission_limit=spec.admission_limit, horizon=20
        )
        fleet.step_interval()
        fleet.step_interval()
        fleet.drain()
        s = fleet.summary()
        assert s["fleet"]["offered"] == sum(
            t["arrived"] for t in s["tenants"].values()
        )
