"""Property-based tests on the paper's MDP invariants — hypothesis when
installed, the seeded fallback sweep from tests/_hyp.py otherwise."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.cluster import default_pipeline, make_trace, PipelineEnv
from repro.core.mdp import (
    Config,
    QoSWeights,
    evaluate,
    feasible,
    pipeline_metrics,
    resource_usage,
    reward,
    qos,
)

PIPE = default_pipeline()
W = QoSWeights()


def cfg_strategy():
    n = PIPE.n_tasks
    return st.tuples(
        st.tuples(*[st.integers(0, len(t.variants) - 1) for t in PIPE.tasks]),
        st.tuples(*[st.integers(1, PIPE.f_max) for _ in range(n)]),
        st.tuples(*[st.sampled_from(PIPE.batch_choices()) for _ in range(n)]),
    ).map(lambda zfb: Config(z=zfb[0], f=zfb[1], b=zfb[2]))


class TestMetrics:
    @given(cfg_strategy(), st.floats(1.0, 500.0))
    @settings(max_examples=200, deadline=None)
    def test_measured_throughput_bounded_by_demand(self, cfg, demand):
        V, C, T, L, E, cap = pipeline_metrics(PIPE, cfg, demand)
        assert T <= demand + 1e-9
        assert T <= cap + 1e-9
        assert abs(E - (demand - cap)) < 1e-6

    @given(cfg_strategy(), st.floats(1.0, 500.0))
    @settings(max_examples=200, deadline=None)
    def test_cost_accuracy_latency_positive(self, cfg, demand):
        V, C, T, L, E, cap = pipeline_metrics(PIPE, cfg, demand)
        assert C > 0 and V > 0 and L > 0

    @given(cfg_strategy(), st.floats(1.0, 500.0))
    @settings(max_examples=200, deadline=None)
    def test_reward_eq7_consistency(self, cfg, demand):
        """Eq.(7): r = Q - beta_c*C - gamma_b*max(b)."""
        m = evaluate(PIPE, cfg, demand, W)
        assert abs(
            m["reward"] - (m["qos"] - W.beta_c * m["C"] - W.gamma_b * max(cfg.b))
        ) < 1e-09
        assert abs(reward(PIPE, cfg, demand, W) - m["reward"]) < 1e-9
        assert abs(qos(PIPE, cfg, demand, W) - m["qos"]) < 1e-9

    @given(cfg_strategy())
    @settings(max_examples=100, deadline=None)
    def test_more_replicas_never_reduce_capacity(self, cfg):
        m1 = evaluate(PIPE, cfg, 100.0, W)
        bigger = Config(
            z=cfg.z,
            f=tuple((min(f + 1, PIPE.f_max) for f in cfg.f)),
            b=cfg.b,
        )
        m2 = evaluate(PIPE, bigger, 100.0, W)
        assert m2["capacity"] >= m1["capacity"] - 1e-9

    @given(cfg_strategy(), st.floats(1.0, 400.0))
    @settings(max_examples=100, deadline=None)
    def test_cold_start_only_hurts(self, cfg, demand):
        m0 = evaluate(PIPE, cfg, demand, W, cold_frac=0.0)
        m1 = evaluate(PIPE, cfg, demand, W, cold_frac=0.3)
        assert m1["capacity"] <= m0["capacity"] + 1e-9
        assert m1["T"] <= m0["T"] + 1e-9

    @given(cfg_strategy())
    @settings(max_examples=100, deadline=None)
    def test_resource_usage_additive(self, cfg):
        total = resource_usage(PIPE, cfg)
        parts = sum(
            (
                PIPE.tasks[n].variants[cfg.z[n]].resource * cfg.f[n]
                for n in range(PIPE.n_tasks)
            )
        )
        assert abs(total - parts) < 1e-9
        assert feasible(PIPE, cfg) == (total <= PIPE.w_max)


class TestEnv:
    def test_deterministic_given_seed(self):
        tr = make_trace("fluctuating", seed=3)
        outs = []
        for _ in range(2):
            env = PipelineEnv(PIPE, tr, seed=3)
            env.reset()
            cfg = env.default_config()
            rs = [env.step(cfg)[1] for _ in range(5)]
            outs.append(rs)
        assert outs[0] == outs[1]

    def test_episode_length(self):
        env = PipelineEnv(PIPE, make_trace("steady_low", seed=0))
        env.reset()
        steps = 0
        done = False
        while not done:
            _, _, done, _ = env.step(env.default_config())
            steps += 1
        assert steps == 120          # 1200 s cycle / 10 s adaptation interval

    def test_switch_penalty_reduces_reward(self):
        tr = make_trace("steady_low", seed=0)
        env1 = PipelineEnv(PIPE, tr)
        env1.reset()
        stay = env1.default_config()
        env1.step(stay)
        _, r_stay, _, _ = env1.step(stay)
        env2 = PipelineEnv(PIPE, tr)
        env2.reset()
        env2.step(stay)
        switched = Config(z=(1,) + stay.z[1:], f=stay.f, b=stay.b)
        _, r_switch, _, i2 = env2.step(switched)
        # same interval, switch pays a cold-start capacity penalty
        assert i2["capacity"] < env1.monitor.latest("throughput") + 1e9
        assert r_switch != r_stay

    def test_state_dim_matches_eq5(self):
        env = PipelineEnv(PIPE, make_trace("steady_low", seed=0))
        s = env.reset()
        assert s.shape == (PIPE.n_tasks * 9,)   # 9 features per task (Eq. 5)


class TestWorkloads:
    @pytest.mark.parametrize("kind", ["steady_low", "fluctuating", "steady_high"])
    def test_traces_positive_and_seeded(self, kind):
        a = make_trace(kind, seed=5)
        b = make_trace(kind, seed=5)
        c = make_trace(kind, seed=6)
        assert (a > 0).all() and len(a) == 1200
        assert np.array_equal(a, b)
        assert not np.array_equal(a, c)

    def test_regime_ordering(self):
        lo = make_trace("steady_low", seed=0).mean()
        hi = make_trace("steady_high", seed=0).mean()
        fl = make_trace("fluctuating", seed=0)
        assert lo < hi
        assert fl.std() > make_trace("steady_low", seed=0).std()
