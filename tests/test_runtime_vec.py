"""Tests for the jitted discrete-event runtime twin (repro.core.runtime_vec):
replay equivalence with the reference ``RuntimeEnv``/``ServingRuntime`` loop
across all registered pipelines (including the placement-aware
``serve3-hetero`` on the ``edge-hetero-3`` cluster), arrival precomputation,
closed-loop vec_rollout invariants, the OPDTrainer vec-runtime branch, and
``train_backend="runtime"`` reproducibility through the Session facade."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import api
from repro.cluster import RuntimeEnv
from repro.core import OPDTrainer, PPOConfig, action_to_config, head_sizes, init_policy
from repro.core import runtime_vec as rv
from repro.core import vecenv
from repro.core.mdp import QoSWeights
from repro.serving import make_arrivals

WEIGHTS = QoSWeights()
HORIZON = 60
N_STEPS = HORIZON // 10


def _random_actions(pipe, rng, n):
    sizes = head_sizes(pipe)
    return np.stack([[rng.integers(0, s) for s in sizes] for _ in range(n)]).astype(
        np.int32
    )


def _reference_episode(pipe, arrivals, actions):
    """Step the real event-driven RuntimeEnv through one action sequence."""
    env = RuntimeEnv(pipe, arrivals, horizon=HORIZON)
    rewards, completed = [], []
    for a in actions:
        _, r, _, info = env.step(action_to_config(pipe, a))
        rewards.append(float(r))
        completed.append(int(info["processed"]))
    return np.asarray(rewards), np.asarray(completed)


class TestTwinEquivalence:
    """The acceptance pin: same arrivals + same config decisions ->
    matching served counts and episode rewards, per registered pipeline."""

    @pytest.mark.parametrize("name", api.list_pipelines())
    def test_replay_matches_runtime_env(self, name):
        pipe = api.get_pipeline(name).build()
        tables = vecenv.tables_from_pipeline(pipe)
        arrivals = make_arrivals("bursty", rate=20, seed=3)
        actions = _random_actions(pipe, np.random.default_rng(0), N_STEPS)

        ref_r, ref_c = _reference_episode(pipe, arrivals, actions)
        ep = rv.episode_arrivals(arrivals, HORIZON)
        out = rv.replay(
            tables,
            ep,
            jnp.asarray(actions),
            n_steps=N_STEPS,
            weights=WEIGHTS,
        )
        twin_c = np.asarray(out["completed"], np.int64)
        twin_r = np.asarray(out["rewards"])

        # event ordering and batch formation are replicated exactly; the
        # float32 clock may move a completion across an interval boundary
        assert np.abs(twin_c - ref_c).max() <= 2, (twin_c, ref_c)
        assert twin_c.sum() == pytest.approx(ref_c.sum(), abs=2)
        assert np.allclose(twin_r, ref_r, atol=0.15), (twin_r, ref_r)

    def test_hetero_placement_interval_rewards(self):
        """serve3-hetero pins the full placement-aware path: node speeds,
        hop latency, cold starts — reward trace matches tightly."""
        pipe = api.get_pipeline("serve3-hetero").build()
        tables = vecenv.tables_from_pipeline(pipe)
        arrivals = make_arrivals("bursty", rate=25, seed=7)
        actions = _random_actions(pipe, np.random.default_rng(5), N_STEPS)
        ref_r, ref_c = _reference_episode(pipe, arrivals, actions)
        ep = rv.episode_arrivals(arrivals, HORIZON)
        out = rv.replay(
            tables,
            ep,
            jnp.asarray(actions),
            n_steps=N_STEPS,
            weights=WEIGHTS,
        )
        assert np.allclose(np.asarray(out["rewards"]), ref_r, atol=0.15)
        assert int(np.asarray(out["completed"]).sum()) > 0


class TestEpisodeArrivals:
    def test_times_match_process_and_pad_inf(self):
        arr = make_arrivals("poisson", rate=12, seed=1)
        ep = rv.episode_arrivals(arr, HORIZON)
        t = np.asarray(arr.times(HORIZON))
        got = np.asarray(ep.times)
        assert np.allclose(got[:len(t)], t.astype(np.float32))
        assert np.all(np.isinf(got[len(t):]))
        # the dispatch window dynamic_slice needs a guaranteed inf tail
        assert got.shape[0] - len(t) >= rv._ARRIVAL_PAD
        assert got.shape[0] % rv._ARRIVAL_BUCKET == 0

    def test_interval_counts_cover_all_arrivals(self):
        arr = make_arrivals("bursty", rate=20, seed=2)
        ep = rv.episode_arrivals(arr, HORIZON)
        t = np.asarray(arr.times(HORIZON))
        assert ep.arrived.shape == (N_STEPS,)
        assert float(jnp.sum(ep.arrived)) == np.count_nonzero(t < HORIZON)

    def test_n_cap_too_small_raises(self):
        arr = make_arrivals("bursty", rate=30, seed=0)
        with pytest.raises(ValueError):
            rv.episode_arrivals(arr, HORIZON, n_cap=rv._ARRIVAL_PAD)

    def test_stack_pads_to_widest(self):
        eps = [
            rv.episode_arrivals(make_arrivals("poisson", rate=r, seed=r), HORIZON)
            for r in (5, 40)
        ]
        batch = rv.stack_episodes(eps)
        assert batch.times.shape[0] == 2
        assert batch.times.shape[1] == max(e.times.shape[0] for e in eps)
        assert np.all(np.isinf(np.asarray(batch.times[0])[eps[0].times.shape[0]:]))


class TestVecRollout:
    B = 4

    def _setup(self, name="serve2"):
        pipe = api.get_pipeline(name).build()
        tables = vecenv.tables_from_pipeline(pipe)
        env = RuntimeEnv(
            pipe,
            make_arrivals("bursty", rate=20, seed=0),
            horizon=HORIZON,
        )
        params = init_policy(jax.random.PRNGKey(0), env.state_dim, head_sizes(pipe))
        eps = rv.stack_episodes(
            [
                rv.episode_arrivals(make_arrivals("bursty", rate=20, seed=i), HORIZON)
                for i in range(self.B)
            ]
        )
        keys = jax.vmap(lambda s: jax.random.fold_in(jax.random.PRNGKey(9), s))(
            jnp.arange(self.B)
        )
        return pipe, tables, params, eps, keys

    def test_shapes_and_finiteness(self):
        pipe, tables, params, eps, keys = self._setup()
        out = rv.vec_rollout(
            params,
            tables,
            eps,
            keys,
            n_steps=N_STEPS,
            weights=WEIGHTS,
        )
        assert out["actions"].shape == (self.B, N_STEPS, len(head_sizes(pipe)))
        assert out["last_value"].shape == (self.B,)
        for k in ("rewards", "values", "logps", "qos", "completed"):
            assert out[k].shape == (self.B, N_STEPS)
            assert np.isfinite(np.asarray(out[k])).all(), k

    @given(st.integers(0, 10_000))
    @settings(max_examples=6, deadline=None)
    def test_permutation_invariant_along_env_axis(self, perm_seed):
        """Each env consumes only its own (arrivals, key): permuting the
        env axis of the inputs permutes every output exactly."""
        _, tables, params, eps, keys = self._setup()
        out = rv.vec_rollout(
            params,
            tables,
            eps,
            keys,
            n_steps=N_STEPS,
            weights=WEIGHTS,
        )
        perm = np.random.default_rng(perm_seed).permutation(self.B)
        eps_p = jax.tree.map(lambda x: x[perm], eps)
        out_p = rv.vec_rollout(
            params,
            tables,
            eps_p,
            keys[perm],
            n_steps=N_STEPS,
            weights=WEIGHTS,
        )
        for k in out:
            assert np.array_equal(np.asarray(out[k])[perm], np.asarray(out_p[k])), k

    def test_rollout_actions_replay_to_same_rewards(self):
        """A vec_rollout trajectory is a real runtime episode: feeding its
        action sequence back through the reference RuntimeEnv yields the
        same rewards."""
        pipe, tables, params, eps, keys = self._setup()
        out = rv.vec_rollout(
            params,
            tables,
            eps,
            keys,
            n_steps=N_STEPS,
            weights=WEIGHTS,
        )
        i = 0
        ref_r, _ = _reference_episode(
            pipe,
            make_arrivals("bursty", rate=20, seed=i),
            np.asarray(out["actions"][i]),
        )
        assert np.allclose(np.asarray(out["rewards"][i]), ref_r, atol=0.15)


class TestTrainerVecRuntime:
    def _factory(self, pipe):
        def arrivals(seed):
            return make_arrivals("bursty", rate=20, seed=seed)

        def make_env(seed):
            return RuntimeEnv(pipe, arrivals(seed), horizon=HORIZON)
        return make_env, arrivals

    def test_vec_runtime_branch_updates_params(self):
        pipe = api.get_pipeline("serve2").build()
        make_env, arrivals = self._factory(pipe)
        tr = OPDTrainer(
            pipe,
            make_env,
            ppo=PPOConfig(epochs=1, expert_freq=2),
            seed=0,
            num_envs=4,
            vec_runtime=arrivals,
        )
        assert tr._vec_runtime is not None
        before = jax.tree.map(jnp.copy, tr.params)
        tr.train_episode(1)                     # 1 % 2 != 0 -> runtime twin
        assert tr.history["expert"] == [False]
        delta = jax.tree.reduce(
            lambda a,
            b: a + b,
            jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), before, tr.params),
        )
        assert delta > 0
        assert np.isfinite(tr.history["loss"]).all()

    def test_expert_episode_steps_real_runtime(self):
        pipe = api.get_pipeline("serve2").build()
        make_env, arrivals = self._factory(pipe)
        tr = OPDTrainer(
            pipe,
            make_env,
            ppo=PPOConfig(epochs=1, expert_freq=1),
            seed=0,
            num_envs=4,
            vec_runtime=arrivals,
        )
        tr.train_episode(1)                     # expert -> legacy RuntimeEnv
        assert tr.history["expert"] == [True]
        assert len(tr.expert_states) > 0


class TestClosedLoopAcceptance:
    def test_vec_trained_matches_legacy_trained_on_hetero_cluster(self):
        """Acceptance (ISSUE 6): an OPD policy trained through the
        vectorized runtime twin matches or beats one trained with the
        legacy per-step RuntimeEnv loop, evaluated closed-loop on
        serve3-hetero (the edge-hetero-3 cluster), at equal tiny budgets."""
        from repro.core import OPDPolicy, run_episode
        pipe = api.get_pipeline("serve3-hetero").build()

        def arrivals(seed):
            return make_arrivals("bursty", rate=20, seed=seed)

        def make_env(seed):
            return RuntimeEnv(pipe, arrivals(seed), horizon=HORIZON)

        def train(vec):
            tr = OPDTrainer(
                pipe,
                make_env,
                ppo=PPOConfig(epochs=2, expert_freq=2),
                seed=0,
                num_envs=4 if vec else 1,
                vec_runtime=arrivals if vec else None,
            )
            tr.train(4)
            return tr.params

        def evaluate(params):
            rs = []
            for seed in (500, 501):
                env = RuntimeEnv(pipe, arrivals(seed), horizon=HORIZON)
                out = run_episode(env, OPDPolicy(pipe, params, greedy=True))
                rs.append(float(np.mean(out["reward"])))
            return float(np.mean(rs))

        legacy = evaluate(train(vec=False))
        vec = evaluate(train(vec=True))
        # equal-budget parity: identical expert episodes dominate learning
        # at this scale, so the twin-trained policy must land in the same
        # closed-loop reward band as the reference-trained one
        assert vec >= legacy - max(2.0, 0.5 * abs(legacy)), (vec, legacy)


class TestSessionRuntimeBackend:
    def _spec(self):
        return api.ExperimentSpec(
            pipeline=api.get_pipeline("serve2"),
            scenario=api.replace(
                api.get_scenario("bursty"),
                rate=20.0,
                seed=4,
                horizon=HORIZON,
            ),
            controller=api.replace(
                api.get_controller("opd"),
                train_episodes=2,
                num_envs=2,
                train_backend="runtime",
            ),
            backend="runtime",
        )

    def test_train_backend_roundtrips_through_json(self):
        spec = self._spec()
        back = api.ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert back == spec
        assert back.controller.train_backend == "runtime"

    def test_unknown_train_backend_rejected(self):
        spec = api.replace(
            self._spec(),
            controller=api.replace(self._spec().controller, train_backend="quantum"),
        )
        with pytest.raises(ValueError, match="train_backend"):
            api.Session.from_spec(spec.to_dict()).train()

    def test_train_reproducible_from_serialized_spec(self):
        """Session.train with train_backend="runtime" is reproducible from
        a serialized ExperimentSpec — every arrival stream and policy draw
        derives from spec seeds."""
        blob = json.dumps(self._spec().to_dict())

        def params_of():
            sess = api.Session.from_spec(blob)
            sess.train()
            return sess.trainer.params, list(sess.trainer.history["reward"])

        p1, h1 = params_of()
        p2, h2 = params_of()
        assert h1 == h2
        same = jax.tree.map(lambda a, b: bool((a == b).all()), p1, p2)
        assert all(jax.tree.leaves(same))
