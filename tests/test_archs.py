"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config — one forward, one train step, one decode step on CPU,
asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import api, steps
from repro.models.config import InputShape
from repro.train import adamw_init

KEY = jax.random.PRNGKey(0)
TRAIN = InputShape("smoke_train", 32, 2, "train")
DECODE = InputShape("smoke_dec", 32, 2, "decode")


def concrete_batch(cfg, shape):
    out = {}
    for k, s in steps.batch_specs(cfg, shape).items():
        if s.dtype == jnp.int32:
            out[k] = jnp.ones(s.shape, jnp.int32)
        else:
            out[k] = jax.random.normal(KEY, s.shape, s.dtype) * 0.1
    return out


@pytest.fixture(scope="module")
def smoke_models():
    return {}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_train_step(name, smoke_models):
    cfg = ARCHS[name].smoke()
    params = api.init_model(KEY, cfg)
    smoke_models[name] = (cfg, params)
    batch = concrete_batch(cfg, TRAIN)
    logits, aux = api.forward(params, batch, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert not jnp.isnan(logits).any()
    train = steps.make_train_step(cfg)
    p2, opt2, metrics = train(params, adamw_init(params), batch)
    assert jnp.isfinite(metrics["loss"])
    assert metrics["grad_norm"] > 0.0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a,
        b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p2),
    )
    assert delta > 0.0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step(name, smoke_models):
    cfg, params = smoke_models.get(name) or (
        ARCHS[name].smoke(),
        api.init_model(KEY, ARCHS[name].smoke()),
    )
    serve = steps.make_serve_step(cfg, DECODE)
    ctx = steps.cache_context(cfg, DECODE)
    cache = api.init_cache(cfg, 2, max(ctx, 1))
    if cfg.family == "audio":
        from repro.models import whisper
        batch = {
            "enc_states": jax.random.normal(KEY, (2, cfg.enc_len, cfg.d_model)) * 0.1
        }
        cache = whisper.prefill_cache(params, batch, cfg, max(ctx, 1))
    logits, cache2 = serve(params, {"tokens": jnp.ones((2, 1), jnp.int32)}, cache)
    assert logits.shape == (2, 1, cfg.vocab)
    assert not jnp.isnan(logits).any()
    assert int(cache2["pos"][0]) == 1
    # a second step advances
    logits, cache3 = serve(params, {"tokens": jnp.ones((2, 1), jnp.int32)}, cache2)
    assert int(cache3["pos"][0]) == 2


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_matches_assignment(name):
    """The FULL config fields are exactly the assigned ones."""
    spec = {
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    }[name]
    cfg = ARCHS[name]
    assert (
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv,
        cfg.d_ff,
        cfg.vocab,
    ) == spec
    if name == "granite-moe-3b-a800m":
        assert (cfg.n_experts, cfg.top_k) == (40, 8)
    if name == "llama4-maverick-400b-a17b":
        assert (cfg.n_experts, cfg.top_k) == (128, 1)
    if name == "zamba2-2.7b":
        assert cfg.ssm_state == 64


def test_prefill_step_dense_returns_cache():
    cfg = ARCHS["llama3.2-1b"].smoke()
    params = api.init_model(KEY, cfg)
    pre = steps.make_prefill_step(cfg)
    shape = InputShape("p", 32, 2, "prefill")
    logits, cache = pre(params, concrete_batch(cfg, shape))
    assert logits.shape == (2, cfg.vocab)
    assert cache["k"].shape == (cfg.n_layers, 2, 32, cfg.n_kv, cfg.head_dim)
    assert int(cache["pos"][0]) == 32
    # prefill cache must continue correctly into decode
    serve = steps.make_serve_step(cfg, DECODE)
    # extend cache to give room for the new token
    import jax.numpy as jnp2
    pad = jnp2.zeros((cfg.n_layers, 2, 8, cfg.n_kv, cfg.head_dim), cache["k"].dtype)
    cache = {
        "k": jnp2.concatenate([cache["k"], pad], axis=2),
        "v": jnp2.concatenate([cache["v"], pad], axis=2),
        "pos": cache["pos"],
    }
    lg, c2 = serve(params, {"tokens": jnp.ones((2, 1), jnp.int32)}, cache)
    assert not jnp.isnan(lg).any()
