"""Distribution correctness: sharding specs are well-formed for every arch,
and the shard_map expert-parallel MoE path is numerically identical to the
local path. Multi-device cases run in a SUBPROCESS with forced host devices
so this pytest session keeps seeing exactly 1 device (the dry-run owns the
512-device configuration)."""
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.compat import abstract_mesh
from repro.configs import ARCHS
from repro.distributed import sharding as shd


class TestShardingSpecs:
    @pytest.mark.parametrize("name", sorted(ARCHS))
    def test_param_specs_divide_evenly(self, name):
        """Every param leaf's spec must divide its dims on the 16x16 mesh —
        checked abstractly (no devices needed)."""
        cfg = ARCHS[name]
        mesh = abstract_mesh((16, 16), ("data", "model"))
        for kind in ("train", "decode"):
            psh = shd.param_shardings(cfg, mesh, kind=kind)
            shapes = jax.eval_shape(
                lambda k: __import__("repro.models.api", fromlist=["api"]).init_model(
                    k,
                    cfg,
                ),
                jax.random.PRNGKey(0),
            )
            for leaf, sh in zip(jax.tree.leaves(shapes), jax.tree.leaves(psh),
                                strict=True):
                for dim, ax in zip(leaf.shape, tuple(sh.spec) + (None,) * 9,
                                   strict=False):
                    if ax is None:
                        continue
                    axes = (ax,) if isinstance(ax, str) else tuple(ax)
                    n = 1
                    for a in axes:
                        n *= mesh.shape[a]
                    assert dim % n == 0, (name, kind, leaf.shape, sh.spec)

    def test_zero1_adds_data_axis_somewhere(self):
        cfg = ARCHS["llama3.2-1b"]
        mesh = abstract_mesh((16, 16), ("data", "model"))
        osh = shd.opt_shardings(cfg, mesh)
        specs = [s.spec for s in jax.tree.leaves(osh)]
        assert any(
            ("data" in str(sp) for sp in specs)
        ), "ZeRO-1 should shard at least one moment leaf over data"


MOE_EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro import nn
    from repro.compat import make_mesh, use_mesh

    key = jax.random.PRNGKey(0)
    p = nn.init_moe(key, 32, 64, 16)          # E=16 -> padded stays 16
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

    y_local, aux_local = nn.moe(p, x, top_k=2)            # no mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        y_ep, aux_ep = jax.jit(lambda p_, x_: nn.moe(p_, x_, top_k=2))(p, x)

    err = float(jnp.abs(y_local - y_ep).max())
    assert err < 1e-4, f"EP vs local mismatch: {err}"
    lb = abs(float(aux_local["lb_loss"]) - float(aux_ep["lb_loss"]))
    assert lb < 1e-4, f"lb_loss mismatch {lb}"
    print("EP==local OK", err)
""")

DRYRUN_SMOKE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import cost_analysis, make_mesh, use_mesh
    from repro.configs import ARCHS
    from repro.distributed import sharding as shd
    from repro.models import api, steps
    from repro.models.config import InputShape
    from repro.train import adamw_init

    # a reduced arch on a tiny 2x4 mesh exercises the full dry-run plumbing
    cfg = ARCHS["granite-moe-3b-a800m"].smoke().replace(
        n_experts=16, top_k=2, n_heads=4, n_kv=4)
    shape = InputShape("t", 64, 8, "train")
    mesh = make_mesh((2, 4), ("data", "model"))
    bs = steps.batch_specs(cfg, shape)
    bsh = shd.batch_shardings(cfg, shape, mesh)
    psh = shd.param_shardings(cfg, mesh)
    zsh = shd.opt_shardings(cfg, mesh)
    params_shape = jax.eval_shape(lambda k: api.init_model(k, cfg),
                                  jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    osh = {"m": zsh, "v": zsh, "step": NamedSharding(mesh, P())}
    step = steps.make_train_step(cfg)
    with use_mesh(mesh):
        compiled = jax.jit(step, in_shardings=(psh, osh, bsh),
                           donate_argnums=(0, 1)).lower(
            params_shape, opt_shape, bs).compile()
    print("compiled OK", cost_analysis(compiled).get("flops", 0) > 0)
""")


def _run_sub(script: str):
    res = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=420,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert res.returncode == 0, f"stdout:{res.stdout}\nstderr:{res.stderr[-2000:]}"
    return res.stdout


class TestMultiDevice:
    def test_moe_expert_parallel_matches_local(self):
        out = _run_sub(MOE_EP_SCRIPT)
        assert "EP==local OK" in out

    def test_dryrun_plumbing_compiles_on_8_devices(self):
        out = _run_sub(DRYRUN_SMOKE_SCRIPT)
        assert "compiled OK True" in out
