"""Integration tests for the OPD RL stack: predictor, policy machinery,
PPO training step, baselines, expert, and the Algorithm-1 loop."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.cluster import default_pipeline, make_trace, PipelineEnv
from repro.core import (
    ExpertPolicy,
    GreedyPolicy,
    IPAPolicy,
    OPDPolicy,
    OPDTrainer,
    PPOConfig,
    RandomPolicy,
    action_to_config,
    compute_gae,
    config_to_action,
    head_sizes,
    init_policy,
    log_prob_entropy,
    run_episode,
    sample_action,
)
from repro.core.mdp import feasible
from repro.core.predictor import (
    HISTORY,
    init_predictor,
    smape,
    train_predictor,
    as_predictor_fn,
)

PIPE = default_pipeline()


def make_env(seed=0, kind="fluctuating"):
    return PipelineEnv(PIPE, make_trace(kind, seed=seed), seed=seed)


class TestPredictor:
    def test_learns_periodic_load(self):
        traces = [make_trace("steady_low", seed=s) for s in range(3)]
        params = train_predictor(traces, scale=120.0, epochs=4, seed=0)
        err = smape(params, [make_trace("steady_low", seed=9)], scale=120.0)
        assert err < 12.0, f"SMAPE {err}% too high on the easy regime"

    def test_predictor_fn_adapter(self):
        params = init_predictor(jax.random.PRNGKey(0))
        fn = as_predictor_fn(params, scale=120.0)
        out = fn(np.ones(HISTORY) * 40.0)
        assert np.isfinite(out)


class TestPolicy:
    def test_action_config_roundtrip(self):
        rng = np.random.default_rng(0)
        for _ in range(20):
            a = np.array([rng.integers(0, s) for s in head_sizes(PIPE)], dtype=np.int32)
            cfg = action_to_config(PIPE, a)
            a2 = config_to_action(PIPE, cfg)
            assert np.array_equal(a, a2)
            assert all(1 <= f <= PIPE.f_max for f in cfg.f)

    def test_sample_action_logprob_consistent(self):
        env = make_env()
        params = init_policy(jax.random.PRNGKey(0), env.state_dim, head_sizes(PIPE))
        s = jnp.asarray(env.reset())
        a, logp, v = sample_action(params, s, jax.random.PRNGKey(1))
        lp, ent, vv = log_prob_entropy(params, s[None], np.asarray(a)[None])
        assert abs(float(lp[0]) - float(logp)) < 1e-4
        assert float(ent[0]) > 0.0
        assert abs(float(vv[0]) - float(v)) < 1e-5


class TestGAE:
    @given(st.lists(st.floats(-5, 5), min_size=2, max_size=50))
    @settings(max_examples=50, deadline=None)
    def test_gae_matches_returns_when_lambda1_gamma1(self, rewards):
        r = np.asarray(rewards, dtype=np.float32)
        values = np.zeros_like(r)
        adv, ret = compute_gae(r, values, 0.0, gamma=1.0, lam=1.0)
        # with V=0, gamma=lam=1: advantage = suffix sums of rewards
        want = np.cumsum(r[::-1])[::-1]
        assert np.allclose(adv, want, atol=1e-4)
        assert np.allclose(ret, want, atol=1e-4)

    def test_gae_zero_when_value_perfect(self):
        r = np.ones(10, dtype=np.float32)
        gamma = 0.9
        # V(s_t) = sum_{k>=0} gamma^k r = geometric tail for infinite horizon;
        # construct exactly: V_t = r + gamma V_{t+1}, V_T(last)=const
        V = np.zeros(11, dtype=np.float32)
        for t in reversed(range(10)):
            V[t] = 1.0 + gamma * V[t + 1]
        adv, _ = compute_gae(r, V[:10], float(V[10]), gamma=gamma, lam=0.95)
        assert np.abs(adv).max() < 1e-5


class TestBaselines:
    def test_all_baselines_feasible_actions(self):
        env = make_env()
        env.reset()
        for pol in (RandomPolicy(PIPE, seed=1), GreedyPolicy(PIPE),
                    IPAPolicy(PIPE), ExpertPolicy(PIPE)):
            cfg = pol(env)
            assert feasible(PIPE, cfg), f"{type(pol).__name__} infeasible"

    def test_qualitative_ordering_matches_paper(self):
        """Paper Figs 4-5: greedy cheapest; IPA highest QoS & most expensive;
        random unstable/most expensive-ish and lowest QoS."""
        res = {}
        for name, pol in [("random", RandomPolicy(PIPE, seed=0)),
                          ("greedy", GreedyPolicy(PIPE)),
                          ("ipa", IPAPolicy(PIPE))]:
            res[name] = run_episode(make_env(0, "steady_low"), pol)
        assert res["greedy"]["cost"].mean() <= res["ipa"]["cost"].mean()
        assert res["ipa"]["qos"].mean() >= res["greedy"]["qos"].mean()
        assert res["random"]["qos"].mean() <= res["greedy"]["qos"].mean()
        assert res["random"]["cost"].std() > res["greedy"]["cost"].std()

    def test_ipa_decision_time_grows_with_variants(self):
        from repro.cluster.perf_model import make_pipeline
        from repro.configs import ARCHS
        small = make_pipeline([[ARCHS["xlstm-125m"]]] * 2, quants=("bf16",))
        big = make_pipeline(
            [[ARCHS["xlstm-125m"]]] * 4,
            quants=("bf16", "int8", "int4"),
        )
        for pipe in (small, big):
            env = PipelineEnv(pipe, make_trace("steady_low", seed=0))
            env.reset()
            IPAPolicy(pipe)(env)
        ipa_s = IPAPolicy(small)
        ipa_b = IPAPolicy(big)
        env_s = PipelineEnv(small, make_trace("steady_low", seed=0))
        env_s.reset()
        env_b = PipelineEnv(big, make_trace("steady_low", seed=0))
        env_b.reset()
        ipa_s(env_s)
        ipa_b(env_b)
        assert ipa_b.decision_times[-1] > ipa_s.decision_times[-1]


class TestOPDTraining:
    def test_ppo_episode_updates_params_and_logs(self):
        tr = OPDTrainer(PIPE, make_env, ppo=PPOConfig(epochs=1, expert_freq=2), seed=0)
        before = jax.tree.map(jnp.copy, tr.params)
        tr.train_episode(1)
        tr.train_episode(2)     # expert episode (freq=2)
        delta = jax.tree.reduce(
            lambda a,
            b: a + b,
            jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), before, tr.params),
        )
        assert delta > 0
        assert len(tr.history["reward"]) == 2
        assert tr.history["expert"] == [False, True]
        assert np.isfinite(tr.history["loss"]).all()

    def test_opd_policy_runs_and_measures_time(self):
        tr = OPDTrainer(PIPE, make_env, ppo=PPOConfig(epochs=1), seed=0)
        pol = OPDPolicy(PIPE, tr.params)
        res = run_episode(make_env(1), pol)
        assert len(res["reward"]) == 120
        assert res["decision_time_total"] > 0
        # OPD decision time per step must be far below the 10 s interval
        assert res["decision_times"].mean() < 0.5

    def test_run_episode_resets_decision_times(self):
        """Reusing one policy object across episodes must not inflate H:
        each run_episode reports that episode's decisions only."""
        tr = OPDTrainer(PIPE, make_env, ppo=PPOConfig(epochs=1), seed=0)
        pol = OPDPolicy(PIPE, tr.params)
        res1 = run_episode(make_env(1), pol)
        res2 = run_episode(make_env(2), pol)
        assert len(res1["decision_times"]) == len(res1["reward"])
        # without the reset this would be 2x the episode length
        assert len(res2["decision_times"]) == len(res2["reward"])
