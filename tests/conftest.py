import os
import sys

# tests run on the single real CPU device (the 512-device override is
# exclusively dryrun.py's)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make tests/_hyp.py (guarded hypothesis import) importable from test modules
sys.path.insert(0, os.path.dirname(__file__))
