"""End-to-end behaviour tests: the full OPD story on a small pipeline —
train briefly with expert guidance, then beat the weakest baselines (the
paper's headline claim, at smoke scale)."""
import numpy as np
import pytest

from repro.cluster import PipelineEnv, make_pipeline, make_trace
from repro.configs import ARCHS
from repro.core import (
    IPAPolicy,
    OPDPolicy,
    OPDTrainer,
    PPOConfig,
    RandomPolicy,
    run_episode,
)


@pytest.fixture(scope="module")
def small_setup():
    pipe = make_pipeline(
        [
            [ARCHS["xlstm-125m"], ARCHS["llama3.2-1b"]],
            [ARCHS["granite-moe-3b-a800m"], ARCHS["starcoder2-3b"]],
        ],
        name="e2e-2stage",
        w_max=32.0,
    )

    def make_env(seed=0, kind="fluctuating"):
        return PipelineEnv(pipe, make_trace(kind, seed=seed), seed=seed)

    trainer = OPDTrainer(pipe, make_env, ppo=PPOConfig(epochs=2, expert_freq=2), seed=0)
    trainer.train(6)
    return pipe, make_env, trainer


def test_training_converges_upward(small_setup):
    _, _, trainer = small_setup
    h = trainer.history
    agent_rewards = [r for r, e in zip(h["reward"], h["expert"], strict=True) if not e]
    # by episode 6 the agent should not be worse than its own first episode
    assert agent_rewards[-1] >= agent_rewards[0] - 1.0


def test_opd_beats_random(small_setup):
    pipe, make_env, trainer = small_setup
    opd = run_episode(make_env(7), OPDPolicy(pipe, trainer.params))
    rnd = run_episode(make_env(7), RandomPolicy(pipe, seed=7))
    assert opd["reward"].mean() > rnd["reward"].mean()


def test_opd_decision_faster_than_solver(small_setup):
    """Fig. 6: OPD decision time ~constant, far below solver enumeration on
    complex pipelines."""
    big = make_pipeline(
        [[ARCHS["xlstm-125m"], ARCHS["llama3.2-1b"], ARCHS["starcoder2-3b"]]] * 4,
        name="big",
        w_max=64.0,
    )
    env = PipelineEnv(big, make_trace("steady_low", seed=0))
    env.reset()
    ipa = IPAPolicy(big)
    ipa(env)

    pipe, make_env, trainer = small_setup
    opd = OPDPolicy(pipe, trainer.params)
    e2 = make_env(3)
    e2.reset()
    opd(e2)        # warm
    opd(e2)
    assert np.mean(opd.decision_times[-1]) < ipa.decision_times[-1] * 5


def test_reward_tracks_objective(small_setup):
    """Reward (Eq. 7) and objective (Eq. 4) must rank configs consistently
    when batch sizes are equal and cost weights are aligned."""
    from repro.core.mdp import Config, QoSWeights, reward, objective
    pipe, _, _ = small_setup
    w = QoSWeights()
    w = QoSWeights(beta_c=w.lam)     # align Eq. 7 and Eq. 4 cost weights
    c1 = Config(z=(0, 0), f=(1, 1), b=(4, 4))
    c2 = Config(z=(3, 3), f=(2, 2), b=(4, 4))
    r1, r2 = reward(pipe, c1, 50.0, w), reward(pipe, c2, 50.0, w)
    o1, o2 = objective(pipe, c1, 50.0, w), objective(pipe, c2, 50.0, w)
    assert (r1 < r2) == (o1 < o2)
