"""Cluster topology & placement-aware configuration (ISSUE 4).

Covers: placement determinism (same spec -> identical Placement across runs
and under env-axis vmap), per-node infeasibility penalties in both envs,
homogeneous-topology equivalence against pinned pre-refactor rewards for
every registered pipeline, spec round-trips, scheduler semantics, and the
closed-loop RuntimeEnv comparison on the heterogeneous edge cell.
"""
import json

import numpy as np
import pytest

from repro import api
from repro.cluster import ClusterTopology, Node, PipelineEnv, RuntimeEnv, make_trace
from repro.cluster.topology import PlacementCursor
from repro.core import action_to_config, head_sizes
from repro.core.mdp import (
    Config,
    ModelVariant,
    Pipeline,
    Task,
    evaluate,
    feasible,
    placement_for,
    resources_feasible,
    QoSWeights,
)
from repro.serving.arrivals import PoissonArrivals

# Pre-refactor PipelineEnv rewards (commit e8358b0): fixed action sequence
# (rng seed 42, one draw per policy head) on make_trace("fluctuating",
# seed=12, seconds=100). The homogeneous scalar pool must stay bit-for-bit.
PINNED_PIPELINE_REWARDS = {
    "paper-4stage": [
        -5.3151365468,
        -4.0462201494,
        -6.5935040844,
        -10.1241661778,
        0.7804440702,
        -3.88291622,
        0.7893590799,
        -1.145420371,
        -11.2171764889,
        -12.052861488,
    ],
    "serve2": [
        1.8797802572,
        3.9428146323,
        -7.6178342665,
        6.6290005852,
        -3.014205002,
        -5.0013625613,
        -1.184573621,
        5.500170073,
        -0.5607011719,
        7.2181643876,
    ],
    "serve3": [
        -4.187239754,
        -8.3480971311,
        -2.2778298527,
        -6.8513507324,
        -9.5763173432,
        -6.1445828676,
        -2.3986653618,
        -8.6811828327,
        -3.1954082609,
        -6.3897825176,
    ],
}

# Pinned RuntimeEnv rewards: serve3 pipeline, PoissonArrivals(18, seed=7),
# horizon 60, the fixed config sequence below. Captured on the homogeneous
# topology after the stale-timer fix (superseded batch-deadline timers are
# dropped instead of poking the reconfigured stage), which changed the
# event stream relative to the pre-topology-refactor pins.
RUNTIME_CFGS = [
    Config(z=(0, 0, 0), f=(2, 2, 2), b=(4, 4, 4)),
    Config(z=(1, 0, 1), f=(2, 2, 2), b=(4, 4, 4)),
    Config(z=(1, 0, 1), f=(3, 3, 3), b=(8, 8, 8)),
    Config(z=(0, 0, 0), f=(2, 2, 2), b=(4, 4, 4)),
    Config(z=(0, 0, 0), f=(2, 2, 2), b=(4, 4, 4)),
    Config(z=(0, 1, 0), f=(1, 1, 1), b=(2, 2, 2)),
]
PINNED_RUNTIME_REWARDS = [
    6.9580128565,
    3.0665564604,
    6.5002657003,
    3.310990728,
    1.8467421393,
    -3.0921084267,
]


def hetero_topo():
    return api.get_cluster("edge-hetero-3").build()


def tiny_pipe(resource=2.0, topo=None):
    """One-stage pipeline with a single variant of known resource size."""
    var = ModelVariant(
        name="v",
        accuracy=0.8,
        cost=resource,
        resource=resource,
        alpha=0.02,
        beta=0.002,
    )
    return Pipeline(
        name="tiny",
        tasks=(Task("t0", (var,)),),
        f_max=8,
        b_max=8,
        w_max=6.0,
        topology=topo,
    )


class TestScheduler:
    def test_same_spec_identical_placement(self):
        """Determinism: the same (topology, resources, replicas) always
        yields the identical Placement object graph."""
        topo = hetero_topo()
        a = topo.place((2.0, 4.0, 8.0), (3, 2, 4))
        b = topo.place((2.0, 4.0, 8.0), (3, 2, 4))
        c = api.get_cluster("edge-hetero-3").build().place((2.0, 4.0, 8.0), (3, 2, 4))
        assert a == b == c

    def test_first_fit_fills_nodes_in_order(self):
        topo = ClusterTopology("t", (Node("a", 4.0), Node("b", 4.0)))
        pl = topo.place((2.0,), (3,))
        assert pl.nodes == ((0, 0, 1),)      # 2+2 on a, overflow to b
        assert pl.node_usage == (4.0, 2.0)
        assert pl.feasible

    def test_fragmentation_infeasible_despite_total_capacity(self):
        """Per-node limits bite where the scalar pool would not: 3 replicas
        of size 2 need 6 <= total 6, but no node can host the third."""
        topo = ClusterTopology("t", (Node("a", 3.0), Node("b", 3.0)))
        pl = topo.place((2.0,), (3,))
        assert not pl.feasible and pl.overflow > 0
        assert sum(pl.node_usage) < 6.0

    def test_hops_and_speeds(self):
        topo = ClusterTopology(
            "t",
            (Node("a", 4.0, speed=2.0), Node("b", 8.0, speed=0.5)),
            hop_latency=0.1,
        )
        pl = topo.place((4.0, 4.0), (1, 2))
        assert pl.nodes == ((0,), (1, 1))    # stage1 no longer fits on a
        assert pl.primary == (0, 1) and pl.n_hops == 1
        assert pl.stage_speed_sum == (2.0, 1.0)
        assert pl.stage_min_speed == (2.0, 0.5)

    def test_trivial_topology_matches_scalar_pool(self):
        topo = ClusterTopology.homogeneous(10.0)
        assert topo.trivial
        ok = topo.place((3.0,), (3,))       # 9 <= 10
        bad = topo.place((3.0,), (4,))      # 12 > 10
        assert ok.feasible and not bad.feasible
        assert bad.overflow == pytest.approx(2.0)

    def test_cursor_reduces_to_scalar_budget_on_trivial(self):
        cur = PlacementCursor(ClusterTopology.homogeneous(10.0))
        assert cur.can_place(3.0, 3)
        assert not cur.can_place(3.0, 4)
        assert not cur.can_place(3.0, 3, reserve=2.0)
        assert cur.place(3.0, 2)
        assert cur.remaining == pytest.approx(4.0)
        # a failed placement still consumes capacity (legacy scalar loop
        # semantics: an infeasible fallback stage exhausted the budget)
        assert not cur.place(3.0, 2)
        assert cur.remaining == pytest.approx(0.0)
        assert not cur.can_place(1.0, 1)

    def test_cursor_respects_per_node_fragmentation(self):
        cur = PlacementCursor(ClusterTopology("t", (Node("a", 3.0), Node("b", 3.0))))
        assert not cur.can_place(2.0, 3)     # 6 <= 6 total, but fragmented
        assert cur.can_place(2.0, 2)


class TestSpecs:
    def test_cluster_spec_roundtrip(self):
        spec = api.get_cluster("edge-hetero-3")
        back = api.ClusterSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert back == spec
        assert back.build() == spec.build()

    def test_builtin_clusters_registered(self):
        assert {"homogeneous", "edge-hetero-3", "edge-constrained"} <= set(
            api.list_clusters()
        )
        with pytest.raises(KeyError):
            api.get_cluster("no-such-cluster")

    def test_homogeneous_builtin_is_trivial_default(self):
        topo = api.get_cluster("homogeneous").build()
        assert topo.trivial and topo.total_capacity == 64.0

    def test_pipeline_spec_with_cluster_roundtrips(self):
        spec = api.get_pipeline("serve3-hetero")
        back = api.PipelineSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert back == spec
        pipe = back.build()
        assert pipe.topology is not None and pipe.topo.n_nodes == 3
        assert pipe.w_max == spec.cluster.total_capacity

    def test_clusterless_pipeline_builds_scalar_pool(self):
        pipe = api.get_pipeline("serve3").build()
        assert pipe.topology is None and pipe.scalar_pool
        assert pipe.topo.trivial and pipe.topo.total_capacity == pipe.w_max


class TestHomogeneousEquivalence:
    @pytest.mark.parametrize("name", sorted(PINNED_PIPELINE_REWARDS))
    def test_pipeline_env_rewards_bit_for_bit(self, name):
        """Acceptance: on the default homogeneous topology, PipelineEnv
        rewards are identical to the pinned pre-refactor values."""
        pipe = api.get_pipeline(name).build()
        env = PipelineEnv(pipe, make_trace("fluctuating", seed=12, seconds=100), seed=0)
        env.reset()
        rng = np.random.default_rng(42)
        for t, pinned in enumerate(PINNED_PIPELINE_REWARDS[name]):
            a = np.array([rng.integers(0, s) for s in head_sizes(pipe)], np.int64)
            _, r, _, _ = env.step(action_to_config(pipe, a))
            assert r == pytest.approx(pinned, abs=1e-9), (name, t)

    def test_runtime_env_rewards_bit_for_bit(self):
        pipe = api.get_pipeline("serve3").build()
        env = RuntimeEnv(pipe, PoissonArrivals(18, seed=7), horizon=60)
        for cfg, pinned in zip(RUNTIME_CFGS, PINNED_RUNTIME_REWARDS, strict=True):
            _, r, _, info = env.step(cfg)
            assert float(r) == pytest.approx(pinned, abs=1e-9)
            assert info["migrations"] == 0    # single node: nothing moves

    def test_explicit_trivial_topology_matches_implicit(self):
        """Pipeline(topology=homogeneous(w_max)) == Pipeline(topology=None)
        reward-for-reward."""
        base = api.get_pipeline("serve2").build()
        explicit = Pipeline(
            name=base.name,
            tasks=base.tasks,
            f_max=base.f_max,
            b_max=base.b_max,
            w_max=base.w_max,
            topology=ClusterTopology.homogeneous(base.w_max),
        )
        trace = make_trace("fluctuating", seed=5, seconds=80)
        rng = np.random.default_rng(7)
        actions = [
            np.array([rng.integers(0, s) for s in head_sizes(base)], np.int64)
            for _ in range(8)
        ]
        for pipe_a, pipe_b in ((base, explicit),):
            ea = PipelineEnv(pipe_a, trace, seed=0)
            eb = PipelineEnv(pipe_b, trace, seed=0)
            ea.reset(), eb.reset()
            for a in actions:
                _, ra, _, _ = ea.step(action_to_config(pipe_a, a))
                _, rb, _, _ = eb.step(action_to_config(pipe_b, a))
                assert ra == rb


class TestPerNodeInfeasibility:
    def _fragmented(self):
        # 3 replicas x 2 chips = 6 == total capacity, but 3+3 nodes can
        # host only one replica each -> per-node infeasible
        topo = ClusterTopology("frag", (Node("a", 3.0), Node("b", 3.0)))
        return tiny_pipe(resource=2.0, topo=topo)

    def test_feasibility_helpers(self):
        pipe = self._fragmented()
        bad = Config(z=(0,), f=(3,), b=(1,))
        ok = Config(z=(0,), f=(2,), b=(1,))
        assert not resources_feasible(pipe, bad) and not feasible(pipe, bad)
        assert resources_feasible(pipe, ok) and feasible(pipe, ok)

    def test_pipeline_env_charges_penalty(self):
        pipe = self._fragmented()
        trace = make_trace("steady_low", seed=0)[:40]
        bad = Config(z=(0,), f=(3,), b=(1,))
        ok = Config(z=(0,), f=(2,), b=(1,))
        env = PipelineEnv(pipe, trace, seed=0)
        env.reset()
        _, r_bad, _, info_bad = env.step(bad)
        env.reset()
        _, r_ok, _, info_ok = env.step(ok)
        assert info_bad["infeasible"] and not info_ok["infeasible"]
        w = QoSWeights()
        m = evaluate(pipe, bad, float(np.mean(trace[:10])), w, cold_frac=0.0)
        assert r_bad == pytest.approx(m["reward"] - 50.0)

    def test_runtime_env_charges_penalty(self):
        pipe = self._fragmented()
        env = RuntimeEnv(pipe, PoissonArrivals(5, seed=1), horizon=20)
        _, _, _, info_bad = env.step(Config(z=(0,), f=(3,), b=(1,)))
        _, _, _, info_ok = env.step(Config(z=(0,), f=(2,), b=(1,)))
        assert info_bad["infeasible"] and not info_ok["infeasible"]


class TestVecenvPlacement:
    def test_placement_deterministic_under_env_axis_vmap(self):
        """Duplicated (state, action, trace) rows under vmap produce
        identical placement-aware rewards and observations per row."""
        import jax
        import jax.numpy as jnp
        from repro.core import vecenv
        pipe = api.get_pipeline("serve3-hetero").build()
        tables = vecenv.tables_from_pipeline(pipe)
        assert tables.n_nodes == 3
        trace = jnp.asarray(make_trace("fluctuating", seed=2, seconds=60), jnp.float32)
        state = vecenv.init_state(tables)
        rng = np.random.default_rng(3)
        a = jnp.asarray([rng.integers(0, s) for s in head_sizes(pipe)], jnp.int32)
        B = 5
        batch_state = jax.tree.map(lambda x: jnp.stack([x] * B), state)
        out = jax.vmap(lambda s: vecenv.step(tables, s, a, trace, QoSWeights()))(
            batch_state
        )
        _, obs, rewards, metrics = out
        assert np.unique(np.asarray(rewards)).size == 1
        assert np.all(np.asarray(obs) == np.asarray(obs)[0])
        assert np.unique(np.asarray(metrics["infeasible"])).size == 1

    def test_vecenv_placement_matches_numpy_scheduler(self):
        """The jitted first-fit takes the same discrete decisions as
        cluster.topology.place for random configurations."""
        import jax.numpy as jnp
        from repro.core import vecenv
        pipe = api.get_pipeline("serve3-hetero").build()
        tables = vecenv.tables_from_pipeline(pipe)
        rng = np.random.default_rng(11)
        for _ in range(25):
            z = tuple((int(rng.integers(0, len(t.variants))) for t in pipe.tasks))
            f = tuple((int(rng.integers(1, pipe.f_max + 1)) for _ in pipe.tasks))
            pl = placement_for(pipe, Config(z=z, f=f, b=(1,) * pipe.n_tasks))
            twin = vecenv._placement(
                tables,
                jnp.asarray(z, jnp.int32),
                jnp.asarray(f, jnp.int32),
            )
            assert np.allclose(
                np.asarray(twin.speed_sum),
                pl.stage_speed_sum,
                atol=1e-05,
            )
            assert np.allclose(
                np.asarray(twin.min_speed),
                pl.stage_min_speed,
                atol=1e-06,
            )
            assert tuple(np.asarray(twin.primary)) == pl.primary
            assert (float(twin.overflow) > 0) == (pl.overflow > 0)
            # per-slot speeds follow the placement assignment order
            if pl.overflow == 0:
                for i, nodes in enumerate(pl.nodes):
                    for r, node in enumerate(nodes):
                        assert np.isclose(
                            float(twin.slot_speed[i, r]),
                            pipe.topo.nodes[node].speed,
                            atol=1e-06,
                        )

    def test_hetero_observation_has_node_columns(self):
        pipe = api.get_pipeline("serve3-hetero").build()
        env = PipelineEnv(pipe, make_trace("steady_low", seed=0), seed=0)
        s = env.reset()
        K = pipe.topo.n_nodes
        assert s.shape == (pipe.n_tasks * (9 + K),)
        assert env.state_dim == s.shape[0]


class TestHeteroClosedLoop:
    """Acceptance: on edge-hetero-3, OPD beats greedy and random in the
    closed-loop RuntimeEnv benchmark (paper-4stage pipeline placed on the
    heterogeneous edge cell, bursty arrivals, measured-telemetry reward).

    Training: 12 expert-guided PPO episodes on the analytic placement-aware
    simulator, keeping the checkpoint with the best greedy-decode reward on
    4 held-out analytic traces (everything derives from fixed seeds, so the
    run is deterministic)."""

    TRAIN_SEED = 5
    EVAL_SEED = 9
    HORIZON = 120

    @pytest.fixture(scope="class")
    def hetero_pipeline(self):
        return api.replace(
            api.get_pipeline("paper-4stage"),
            cluster=api.get_cluster("edge-hetero-3"),
        )

    def _serve(self, pipeline, name, params=None):
        exp = api.ExperimentSpec(
            pipeline=pipeline,
            scenario=api.replace(
                api.get_scenario("bursty"),
                rate=25.0,
                seed=self.EVAL_SEED,
                horizon=self.HORIZON,
            ),
            controller=api.replace(
                api.get_controller(name),
                seed=self.EVAL_SEED,
                train_episodes=0,
            ),
            backend="runtime",
        )
        sess = api.Session.from_spec(exp)
        if params is not None:
            sess.with_params(params)
        rep = sess.serve()
        return float(np.mean(rep["rewards"])), rep

    def test_opd_beats_greedy_and_random(self, hetero_pipeline):
        import jax
        from repro.core import OPDTrainer, PPOConfig, run_episodes_vectorized
        pipe = hetero_pipeline.build()
        scen = api.replace(
            api.get_scenario("bursty"),
            rate=25.0,
            seed=self.TRAIN_SEED,
            horizon=self.HORIZON,
        )

        def make_env(s):
            return PipelineEnv(pipe, scen.train_trace(s, seconds=600), seed=s)

        val_traces = np.stack(
            [scen.train_trace(1000 + i, seconds=600) for i in range(4)]
        )
        tr = OPDTrainer(
            pipe,
            make_env,
            ppo=PPOConfig(expert_freq=2),
            seed=self.TRAIN_SEED,
            num_envs=2,
        )
        best, best_val = None, -np.inf
        for ep in range(1, 13):
            tr.train_episode(ep, env_seed=ep)
            val = float(
                np.mean(run_episodes_vectorized(pipe, tr.params, val_traces)["rewards"])
            )
            if val > best_val:
                best, best_val = jax.tree.map(np.asarray, tr.params), val

        opd, rep = self._serve(hetero_pipeline, "opd", params=best)
        greedy, _ = self._serve(hetero_pipeline, "greedy")
        random_, _ = self._serve(hetero_pipeline, "random")
        assert opd > greedy, (opd, greedy)
        assert opd > random_, (opd, random_)
        # every admitted request still completes on the hetero cluster
        assert rep["summary"]["served"] == rep["summary"]["arrived"]
