"""Tests for the declarative control-plane API (repro.api): spec JSON
round-trips, registries, the Observation/Controller protocol, action/config
inversion across every registered pipeline, and Session reproducibility."""
import json

import numpy as np
import pytest

from repro import api
from repro.cluster import PipelineEnv, default_pipeline, make_trace
from repro.core import (
    GreedyPolicy,
    IPAPolicy,
    RandomPolicy,
    action_to_config,
    config_to_action,
    head_sizes,
)
from repro.core.controller import Observation
from repro.core.mdp import feasible
from repro.serving.arrivals import arrivals_from_dict, make_arrivals


def _json_roundtrip(d: dict) -> dict:
    blob = json.dumps(d)
    return json.loads(blob)


class TestSpecRoundtrips:
    @pytest.mark.parametrize("name", api.list_pipelines())
    def test_pipeline_spec(self, name):
        spec = api.get_pipeline(name)
        assert api.PipelineSpec.from_dict(_json_roundtrip(spec.to_dict())) == spec

    @pytest.mark.parametrize("name", api.list_scenarios())
    def test_scenario_spec(self, name):
        spec = api.get_scenario(name)
        assert api.ScenarioSpec.from_dict(_json_roundtrip(spec.to_dict())) == spec

    @pytest.mark.parametrize("name", api.list_controllers())
    def test_controller_spec(self, name):
        spec = api.get_controller(name)
        assert api.ControllerSpec.from_dict(_json_roundtrip(spec.to_dict())) == spec

    def test_experiment_spec_nested(self):
        exp = api.ExperimentSpec(
            pipeline=api.get_pipeline("serve2"),
            scenario=api.replace(api.get_scenario("ramp"), rate=40.0, seed=5),
            controller=api.replace(api.get_controller("opd"), train_episodes=2),
            backend="analytic",
            seq_len=16,
        )
        back = api.ExperimentSpec.from_dict(_json_roundtrip(exp.to_dict()))
        assert back == exp

    def test_arrival_process_spec_constructors(self):
        for scenario in ("bursty", "poisson", "ramp", "trace"):
            p = make_arrivals(scenario, rate=30.0, seed=4)
            q = arrivals_from_dict(_json_roundtrip(p.to_dict()))
            assert type(q) is type(p)
            assert np.allclose(p.rates(50), q.rates(50))
            assert np.array_equal(p.generate(50), q.generate(50))


class TestRegistries:
    def test_builtins_registered(self):
        assert {"paper-4stage", "serve2", "serve3"} <= set(api.list_pipelines())
        assert {
            "bursty",
            "poisson",
            "ramp",
            "trace",
            "steady_low",
            "fluctuating",
            "steady_high",
        } <= set(api.list_scenarios())
        assert {"opd", "greedy", "ipa", "random", "expert"} <= set(
            api.list_controllers()
        )

    def test_unknown_names_raise(self):
        with pytest.raises(KeyError):
            api.get_pipeline("no-such-pipeline")
        with pytest.raises(KeyError):
            api.get_scenario("no-such-scenario")
        with pytest.raises(KeyError):
            api.get_controller("no-such-controller")

    def test_paper_pipeline_matches_default(self):
        """The registered paper-4stage spec builds the same pipeline the
        perf model's default_pipeline hard-codes."""
        a, b = api.get_pipeline("paper-4stage").build(), default_pipeline()
        assert a.n_tasks == b.n_tasks
        for ta, tb in zip(a.tasks, b.tasks, strict=True):
            assert tuple((v.name for v in ta.variants)) == tuple(
                (v.name for v in tb.variants)
            )
        assert (a.f_max, a.b_max, a.w_max) == (b.f_max, b.b_max, b.w_max)

    def test_register_custom(self):
        spec = api.PipelineSpec("tiny-test", (("xlstm-125m",),), quants=("bf16",))
        api.register_pipeline(spec)
        assert api.get_pipeline("tiny-test") == spec
        pipe = spec.build()
        assert pipe.n_tasks == 1 and len(pipe.tasks[0].variants) == 1


class TestActionConfigInversion:
    @pytest.mark.parametrize("name", ("paper-4stage", "serve2", "serve3"))
    def test_inversion_across_registered_pipelines(self, name):
        pipe = api.get_pipeline(name).build()
        rng = np.random.default_rng(0)
        for _ in range(25):
            a = np.array([rng.integers(0, s) for s in head_sizes(pipe)], dtype=np.int32)
            cfg = action_to_config(pipe, a)
            assert np.array_equal(config_to_action(pipe, cfg), a)
            assert all(
                (
                    0 <= z < len(t.variants)
                    for (z, t) in zip(cfg.z, pipe.tasks, strict=True)
                )
            )
            assert all(1 <= f <= pipe.f_max for f in cfg.f)
            assert all(1 <= b <= pipe.b_max for b in cfg.b)


class TestControllerProtocol:
    def test_observe_is_public_and_consistent(self):
        pipe = api.get_pipeline("serve2").build()
        env = PipelineEnv(pipe, make_trace("steady_low", seed=0), seed=0)
        obs = env.observe()
        assert isinstance(obs, Observation)
        assert obs.state.shape == (pipe.n_tasks * 9,)
        assert obs.config == env.cfg
        assert obs.predicted_load == pytest.approx(env._predicted_load())

    def test_decide_equals_legacy_call(self):
        """New decide(obs) and the back-compat policy(env) shim agree."""
        pipe = api.get_pipeline("serve2").build()
        env = PipelineEnv(pipe, make_trace("fluctuating", seed=1), seed=1)
        env.reset()
        for pol_new, pol_old in ((GreedyPolicy(pipe), GreedyPolicy(pipe)),
                                 (IPAPolicy(pipe), IPAPolicy(pipe)),
                                 (RandomPolicy(pipe, 3), RandomPolicy(pipe, 3))):
            assert pol_new.decide(env.observe()) == pol_old(env)

    def test_decisions_feasible(self):
        pipe = api.get_pipeline("serve3").build()
        env = PipelineEnv(pipe, make_trace("steady_high", seed=2), seed=2)
        obs = env.observe()
        for name in ("greedy", "ipa", "random", "expert"):
            spec = api.get_controller(name)
            pol = api.controller_factory(name)(spec, pipe, None)
            assert feasible(pipe, pol.decide(obs)), name


class TestSession:
    def _exp(self, **kw):
        base = dict(
            pipeline=api.get_pipeline("serve2"),
            scenario=api.replace(api.get_scenario("bursty"), horizon=30, seed=3),
            controller=api.get_controller("greedy"),
        )
        base.update(kw)
        return api.ExperimentSpec(**base)

    def test_runtime_reproducible_from_json(self):
        """Acceptance: a JSON-serialized ExperimentSpec reproduces the run
        bit-for-bit — identical rewards and telemetry."""
        exp = self._exp()
        r1 = api.run_experiment(exp)
        r2 = api.run_experiment(json.dumps(exp.to_dict()))
        assert r1["rewards"] == r2["rewards"]
        assert r1["qos"] == r2["qos"]
        assert r1["latency"] == r2["latency"]
        assert r1["configs"] == r2["configs"]
        assert r1["summary"]["served"] == r2["summary"]["served"]
        assert r1["summary"]["p95"] == r2["summary"]["p95"]

    def test_analytic_backend_matches_run_episode(self):
        """Session's analytic loop reproduces the legacy run_episode path."""
        from repro.core import run_episode
        exp = self._exp(
            scenario=api.replace(api.get_scenario("fluctuating"), seed=9, horizon=300),
            backend="analytic",
        )
        rep = api.run_experiment(exp)
        pipe = exp.pipeline.build()
        env = PipelineEnv(pipe, exp.scenario.eval_trace(), seed=9)
        legacy = run_episode(env, GreedyPolicy(pipe))
        assert np.allclose(rep["rewards"], legacy["reward"])
        assert np.allclose(rep["qos"], legacy["qos"])

    def test_serve_twice_identical(self):
        sess = api.Session.from_spec(self._exp())
        r1 = dict(sess.serve())
        r2 = sess.serve()
        assert r1["rewards"] == r2["rewards"]

    def test_session_report_runs_on_demand(self):
        rep = api.Session.from_spec(self._exp()).report()
        assert rep["rewards"] and rep["summary"]["served"] > 0
        json.dumps(rep)          # the whole report is a JSON-safe artifact

    def test_trainable_controller_requires_episodes(self):
        exp = self._exp(
            controller=api.replace(api.get_controller("opd"), train_episodes=0)
        )
        with pytest.raises(RuntimeError):
            api.Session.from_spec(exp).serve()


class TestOPDWarmup:
    def test_warmup_excluded_and_key_decorrelated(self):
        """The jit warmup burns a throwaway subkey: it never lands in
        decision_times, and the first real decision does not reuse the
        warmup's PRNG state."""
        import jax
        from repro.core import OPDPolicy, init_policy
        pipe = api.get_pipeline("serve2").build()
        env = PipelineEnv(pipe, make_trace("steady_low", seed=0), seed=0)
        params = init_policy(jax.random.PRNGKey(0), env.state_dim, head_sizes(pipe))
        pol = OPDPolicy(pipe, params, greedy=False, seed=5)
        key0 = pol.key
        obs = env.observe()
        pol.decide(obs)
        assert len(pol.decision_times) == 1     # warmup not timed
        # two splits consumed: one thrown away by warmup, one for the
        # decision — the decision subkey differs from the warmup subkey
        _, warm = jax.random.split(key0)
        # intentional reuse: re-derive both subkey chains from the same key0
        k0a = jax.random.split(key0)[0]  # reprolint: ignore[RPL001]
        k1, real = jax.random.split(k0a)
        assert not np.array_equal(np.asarray(warm), np.asarray(real))
        assert np.array_equal(np.asarray(pol.key), np.asarray(k1))
        pol.decide(obs)
        assert len(pol.decision_times) == 2
