"""GQA single-token decode attention — Pallas TPU kernel.

The decode hot loop of decode_32k / long_500k: one query token per sequence
against a long KV cache. This is memory-bound (arithmetic intensity ~ group
size), so the kernel is organised to stream K/V through VMEM exactly once:

  grid = (B, Hkv, C/bk), last dim sequential with online-softmax scratch.
  Per program: q tile [group, D] (all query heads of one kv head — the
  GQA group is folded into the matmul M dimension so the MXU tile is
  [group, D] x [D, bk] instead of a degenerate [1, D] GEMV).

Valid-length masking supports both contiguous caches (pos < n_valid) and
ring-buffer window caches (mask supplied per slot by the wrapper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _dec_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [g, D]
    k = k_ref[0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0].astype(jnp.float32)                  # [bk, D]
    valid = mask_ref[0]                               # [bk] bool
    s = q @ k.T                                       # [g, bk]
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        denom = l_scr[...]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, valid_mask, *, bk: int = 512, interpret: bool = True):
    """q [B, 1, H, D]; k, v [B, C, Hkv, D]; valid_mask [B, C] -> [B, 1, H, D]."""
    B, _, H, D = q.shape
    C = k.shape[1]
    Hkv = k.shape[2]
    g = H // Hkv
    bk = min(bk, C)
    assert C % bk == 0
    n_k = C // bk
    scale = 1.0 / (D ** 0.5)

    # q -> [B*Hkv, g, D]; kv -> [B*Hkv, C, D]
    qf = q[:, 0].reshape(B, Hkv, g, D).reshape(B * Hkv, g, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    maskf = jnp.repeat(valid_mask, Hkv, axis=0)       # [B*Hkv, C]

    def q_map(bh, _h, ik):
        return (bh, 0, 0)

    def kv_map(bh, _h, ik):
        return (bh, ik, 0)

    def mask_map(bh, _h, ik):
        return (bh, ik)

    kernel = functools.partial(_dec_kernel, scale=scale, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, 1, n_k),
        in_specs=[
            pl.BlockSpec((1, g, D), q_map),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk), mask_map),
        ],
        out_specs=pl.BlockSpec((1, g, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, maskf)
    return out.reshape(B, Hkv * g, D)[:, None].reshape(B, 1, H, D)
