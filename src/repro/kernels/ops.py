"""Public jit'd wrappers for the Pallas kernels.

On CPU (this dev container) kernels run in interpret mode — the kernel body
executes in Python with real dataflow, validating correctness against
ref.py; on TPU the same pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    bq: int = 128, bk: int = 128):
    """q [B, S, H, D]; k, v [B, S, Hkv, D] -> [B, S, H, D]."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=_interpret())


def decode_attention(q, k, v, valid_mask, *, bk: int = 512):
    """q [B, 1, H, D]; k, v [B, C, Hkv, D]; valid_mask [B, C] -> [B, 1, H, D]."""
    return _da.decode_attention(q, k, v, valid_mask, bk=bk,
                                interpret=_interpret())
