"""Flash attention (prefill) — Pallas TPU kernel.

Design (TPU-native, not a CUDA port):
  grid = (B * Hkv * group, Sq/bq, Skv/bk); the last grid dimension is
  "arbitrary" (sequential revisit) so the online-softmax running state
  (m, l, acc) lives in VMEM scratch across kv blocks. Q/K/V blocks are
  VMEM tiles via BlockSpec; block shapes default to (128, 128) × head_dim,
  MXU-aligned (head_dim is 64/80/128 for the assigned archs; the compiler
  pads 80 -> 128 lanes).

Causal + sliding-window masking is block-level: fully-masked kv blocks are
skipped with pl.when (no FLOPs, no HBM traffic beyond the prefetch of the
block — a production version would prune them from the grid), diagonal /
window-edge blocks get an element mask from broadcasted iota.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               bq: int, bk: int, scale: float, causal: bool,
               window: int | None, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    # block-level reachability predicate (skips fully-masked kv blocks)
    pred = jnp.asarray(True)
    if causal:
        pred = pred & (k_start <= q_start + bq - 1)
    if window is not None:
        pred = pred & (k_start + bk - 1 > q_start - window)

    @pl.when(pred)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # [bq, D]
        k = k_ref[0].astype(jnp.float32)                    # [bk, D]
        v = v_ref[0].astype(jnp.float32)                    # [bk, D]
        s = q @ k.T                                         # [bq, bk]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                 # [bq, 1]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                              # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        denom = l_scr[...]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q [B, S, H, D]; k, v [B, S, Hkv, D] -> [B, S, H, D]."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0
    n_q, n_k = S // bq, S // bk
    scale = 1.0 / (D ** 0.5)

    # [B, S, H, D] -> [B*H, S, D]; kv head for flat q-head j: (j % H) // g
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)

    def q_map(h, iq, ik):
        return (h, iq, 0)

    def kv_map(h, iq, ik):
        return ((h // H) * Hkv + (h % H) // g, ik, 0)

    kernel = functools.partial(_fa_kernel, bq=bq, bk=bk, scale=scale,
                               causal=causal, window=window, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), q_map),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
