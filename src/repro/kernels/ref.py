"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """q [B, S, H, D]; k, v [B, S, Hkv, D] -> [B, S, H, D] (f32 math)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, S, Hkv, g, D).astype(jnp.float32)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k.astype(jnp.float32)) / (D ** 0.5)
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask = idx[None, :] <= idx[:, None]
    if window is not None:
        mask = mask & (idx[None, :] > idx[:, None] - window)
    logits = jnp.where(mask[None, None, None], logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def decode_attention_ref(q, k, v, valid_mask):
    """q [B, 1, H, D]; k, v [B, C, Hkv, D]; valid_mask [B, C] -> [B, 1, H, D]."""
    B, _, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, 1, Hkv, g, D).astype(jnp.float32)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k.astype(jnp.float32)) / (D ** 0.5)
    mask = valid_mask[:, None, None, None, :]
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)
