"""Pallas TPU kernels for the serving data plane (validated in interpret
mode on CPU): flash_attention (prefill) and decode_attention (GQA decode
against long KV caches). ops.py = jit wrappers, ref.py = jnp oracles."""
from repro.kernels import ops, ref
