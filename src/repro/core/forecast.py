"""Multi-horizon load forecasting (paper §IV-A, upgraded per ROADMAP).

The paper's predictor emits ONE number — the max load over the next 20 s.
Proactive control needs more: to pre-warm a variant whose cold start takes
``COLD_START_SECONDS``, the controller must see the burst *at least* a cold
start ahead; to arbitrate fleet capacity it wants the load over exactly the
next adaptation interval. This module generalises the predictor into a
multi-horizon forecaster emitting, from one shared backbone pass, the max
load over each horizon in ``HORIZONS`` = {5, 10, 20, 60} s.

Two backbones share the training loop, dataset windowing and eval:

- ``"lstm"``  — the paper-faithful 25-unit LSTM (``nn.lstm``) + dense head;
- ``"mlstm"`` — an xLSTM matrix-memory block (``nn.xlstm.mlstm_parallel``,
  parallelisable over the 120 s window) over an embedded load sequence,
  with a residual + RMSNorm read-out at the last position.

Inputs are telemetry windows [history, C]: channel 0 is the per-second
arrival count (``Monitor.load_history`` / ``Telemetry.load_history``);
optional extra channels carry per-stage queue depth and utilization
(``telemetry_trace`` assembles them from a live ``ServingRuntime``).
Targets are the max of channel 0 over each future horizon window.

``as_forecast_fn`` adapts trained params to the closed loop: the returned
callable maps a load history to one prediction per horizon and advertises
``.horizons`` / ``.min_history`` so environments can fall back to the
last-observed load until a full window of real measurements exists (the
Monitor left-pads cold histories with a constant — a distribution the
forecaster never trained on).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.train import adamw_init, adamw_update

HISTORY = 120                  # seconds of load history per window (paper: 2 min)
HORIZONS = (5, 10, 20, 60)     # forecast horizons (s): prewarm lead times,
#                                the adaptation interval, the paper's 20 s
MLSTM_DIM = 16                 # mLSTM backbone model dim (2 heads, expand 2)
MLSTM_HEADS = 2

BACKBONES = ("lstm", "mlstm")


# ------------------------------------------------------------- dataset ----


def make_forecast_dataset(traces, *, history: int = HISTORY,
                          horizons: tuple[int, ...] = HORIZONS,
                          scale: float, channel_scales=None):
    """Sliding telemetry windows -> (X [M, history, C], y [M, H]).

    ``traces`` is a list of [T] load arrays or [T, C] telemetry arrays
    (channel 0 = load). Targets are the max of channel 0 over each future
    window ``(t, t+h]``. Channel 0 is normalised by ``scale``; extra
    channels by ``channel_scales`` (default: per-channel max over the
    training data, clamped >= 1). Returns the channel scales actually used
    so eval/serving normalise identically."""
    horizons = tuple(int(h) for h in horizons)
    hmax = max(horizons)
    mats = [np.asarray(tr, dtype=np.float32).reshape(len(tr), -1)
            for tr in traces]
    C = mats[0].shape[1]
    if any(m.shape[1] != C for m in mats):
        raise ValueError("all traces must have the same channel count")
    if channel_scales is None:
        rest = (np.maximum([np.abs(m[:, 1:]).max(axis=0) for m in mats],
                           1.0).max(axis=0) if C > 1 else np.empty(0))
        channel_scales = np.concatenate([[scale], rest]).astype(np.float32)
    channel_scales = np.asarray(channel_scales, dtype=np.float32)
    xs, ys = [], []
    for m in mats:
        for s in range(0, len(m) - history - hmax + 1):
            xs.append(m[s:s + history])
            fut = m[s + history:s + history + hmax, 0]
            ys.append([fut[:h].max() for h in horizons])
    X = np.asarray(xs, dtype=np.float32) / channel_scales
    y = np.asarray(ys, dtype=np.float32) / channel_scales[0]
    return X, y, channel_scales


def telemetry_trace(runtime, *, seconds: int | None = None) -> np.ndarray:
    """Assemble a [T, 1 + 2*n_stages] training trace from a live runtime's
    telemetry: per-second arrivals (channel 0), per-stage mean queue depth
    at dispatch, and per-stage utilization (service-seconds charged per
    second per replica). Seconds with no dispatch carry the last observed
    queue depth forward (0 before the first)."""
    tel = runtime.telemetry
    T = int(seconds if seconds is not None else np.ceil(runtime.now))
    S = len(runtime.stages)
    out = np.zeros((T, 1 + 2 * S), dtype=np.float32)
    out[:, 0] = tel.load_history(T, T)
    depth_sum = np.zeros((T, S))
    depth_cnt = np.zeros((T, S))
    for b in tel.batches:
        s = int(b.time)
        if 0 <= s < T:
            depth_sum[s, b.stage] += b.queue_depth
            depth_cnt[s, b.stage] += 1
            out[s, 1 + S + b.stage] += b.service
    last = np.zeros(S)
    for s in range(T):
        for i in range(S):
            if depth_cnt[s, i]:
                last[i] = depth_sum[s, i] / depth_cnt[s, i]
            out[s, 1 + i] = last[i]
    for i, stage in enumerate(runtime.stages):
        out[:, 1 + S + i] /= max(stage.replicas, 1)
    return out


# -------------------------------------------------------------- model ----


def init_forecaster(key, *, backbone: str = "lstm", in_dim: int = 1,
                    horizons: tuple[int, ...] = HORIZONS, hidden: int = 25,
                    dim: int = MLSTM_DIM, n_heads: int = MLSTM_HEADS):
    """Params for one backbone + a dense head with one unit per horizon."""
    H = len(horizons)
    if backbone == "lstm":
        k1, k2 = jax.random.split(key)
        return {"lstm": nn.init_lstm(k1, in_dim, hidden),
                "out": nn.init_linear(k2, hidden, H, bias=True)}
    if backbone == "mlstm":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"embed": nn.init_linear(k1, in_dim, dim, bias=True),
                "mlstm": nn.init_mlstm(k2, dim, n_heads),
                "norm": nn.init_rmsnorm(dim),
                "out": nn.init_linear(k3, dim, H, bias=True)}
    raise ValueError(f"unknown backbone {backbone!r} (one of: {BACKBONES})")


@partial(jax.jit, static_argnames=("backbone", "n_heads"))
def forecast_batch(params, x, *, backbone: str = "lstm",
                   n_heads: int = MLSTM_HEADS):
    """x [B, history, C] (normalised) -> predicted max loads [B, H]."""
    if backbone == "lstm":
        _, (hT, _) = nn.lstm_scan(params["lstm"], x)
        return nn.linear(params["out"], hT)
    h = nn.linear(params["embed"], x)
    h = h + nn.mlstm_parallel(params["mlstm"], h, n_heads=n_heads)
    return nn.linear(params["out"], nn.rmsnorm(params["norm"], h[:, -1]))


@partial(jax.jit, static_argnames=("backbone", "n_heads"))
def _train_step(params, opt, xb, yb, lr, *, backbone, n_heads):
    def loss_fn(p):
        pred = forecast_batch(p, xb, backbone=backbone, n_heads=n_heads)
        return jnp.mean((pred - yb) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt = adamw_update(params, grads, opt, lr=lr, weight_decay=0.0)
    return params, opt, loss


def train_forecaster(traces, *, backbone: str = "lstm", scale: float,
                     horizons: tuple[int, ...] = HORIZONS,
                     history: int = HISTORY, hidden: int = 25,
                     dim: int = MLSTM_DIM, n_heads: int = MLSTM_HEADS,
                     epochs: int = 8, batch: int = 256, seed: int = 0,
                     lr: float = 5e-3, log=None):
    """Shared training loop for both backbones (MSE on normalised targets,
    cosine lr decay, output bias started at the per-horizon target mean).
    Returns ``(params, channel_scales)``. Raises on an empty dataset; the
    batch size is clamped to the dataset so short traces still train."""
    X, y, channel_scales = make_forecast_dataset(
        traces, history=history, horizons=horizons, scale=scale)
    if len(X) == 0:
        raise ValueError(
            f"empty forecast dataset: need traces longer than "
            f"history + max(horizons) = {history + max(horizons)} s")
    batch = min(int(batch), len(X))
    rng = np.random.default_rng(seed)
    params = init_forecaster(jax.random.PRNGKey(seed), backbone=backbone,
                             in_dim=X.shape[-1], horizons=horizons,
                             hidden=hidden, dim=dim, n_heads=n_heads)
    params["out"]["b"] = params["out"]["b"] + jnp.asarray(y.mean(axis=0))
    opt = adamw_init(params)
    steps_per_epoch = max(1, (len(X) - batch) // batch + 1)
    n_steps = steps_per_epoch * epochs
    step = 0
    for e in range(epochs):
        idx = rng.permutation(len(X))
        losses = []
        for s in range(0, len(X) - batch + 1, batch):
            sel = idx[s:s + batch]
            cur_lr = lr * (0.55 + 0.45 * np.cos(np.pi * step / n_steps))
            params, opt, loss = _train_step(
                params, opt, jnp.asarray(X[sel]), jnp.asarray(y[sel]),
                jnp.float32(cur_lr), backbone=backbone, n_heads=n_heads)
            losses.append(float(loss))
            step += 1
        if log:
            log(f"forecaster[{backbone}] epoch {e}: mse={np.mean(losses):.5f}")
    return params, channel_scales


# ---------------------------------------------------------------- eval ----


def smape_horizons(params, traces, *, backbone: str = "lstm", scale: float,
                   horizons: tuple[int, ...] = HORIZONS,
                   history: int = HISTORY, n_heads: int = MLSTM_HEADS,
                   channel_scales=None) -> dict[int, float]:
    """Per-horizon symmetric MAPE (%) on held-out traces (paper: ~6%)."""
    X, y, _ = make_forecast_dataset(traces, history=history,
                                    horizons=horizons, scale=scale,
                                    channel_scales=channel_scales)
    pred = np.asarray(forecast_batch(params, jnp.asarray(X),
                                     backbone=backbone, n_heads=n_heads))
    err = (2.0 * np.abs(pred - y)
           / (np.abs(pred) + np.abs(y) + 1e-9)).mean(axis=0) * 100.0
    return {int(h): float(e) for h, e in zip(horizons, err, strict=True)}


def pinball_horizons(params, traces, *, q: float = 0.9,
                     backbone: str = "lstm", scale: float,
                     horizons: tuple[int, ...] = HORIZONS,
                     history: int = HISTORY, n_heads: int = MLSTM_HEADS,
                     channel_scales=None) -> dict[int, float]:
    """Per-horizon quantile (pinball) loss of the point forecast at level
    ``q`` — penalises under-forecasts ``q/(1-q)``× more than over-forecasts,
    the asymmetry that matters when an under-forecast means a missed
    pre-warm. Reported in load units (de-normalised)."""
    X, y, _ = make_forecast_dataset(traces, history=history,
                                    horizons=horizons, scale=scale,
                                    channel_scales=channel_scales)
    pred = np.asarray(forecast_batch(params, jnp.asarray(X),
                                     backbone=backbone, n_heads=n_heads))
    diff = (y - pred) * scale
    loss = np.maximum(q * diff, (q - 1.0) * diff).mean(axis=0)
    return {int(h): float(v) for h, v in zip(horizons, loss, strict=True)}


# ------------------------------------------------------------- serving ----


def as_forecast_fn(params, *, scale: float, backbone: str = "lstm",
                   horizons: tuple[int, ...] = HORIZONS,
                   history: int = HISTORY, n_heads: int = MLSTM_HEADS,
                   channel_scales=None):
    """Adapter for the envs: load/telemetry history -> one predicted max
    load per horizon (np.ndarray [H], de-normalised). The fn advertises
    ``.horizons`` and ``.min_history`` so callers (``_ConfigEnvBase``,
    ``FleetRuntime``) can fall back to the last-observed load until a full
    window of real measurements exists."""
    scales = (np.asarray(channel_scales, dtype=np.float32)
              if channel_scales is not None
              else np.asarray([scale], dtype=np.float32))

    def fn(hist: np.ndarray) -> np.ndarray:
        h = np.asarray(hist, dtype=np.float32).reshape(len(hist), -1)
        h = h[-history:] / scales[:h.shape[1]]
        pred = forecast_batch(params, jnp.asarray(h)[None],
                              backbone=backbone, n_heads=n_heads)
        return np.asarray(pred[0]) * scale

    fn.horizons = tuple(int(h) for h in horizons)
    fn.min_history = int(history)
    fn.backbone = backbone
    return fn
