"""Vectorized pure-JAX rollout engine for OPD training.

Re-expresses the analytic ``PipelineEnv`` dynamics — Eq. (1)-(4)/(7) scoring,
arrival-trace windowing, and the policy's action -> config decoding — as pure
``jax.numpy`` functions: one environment advances with the jitted ``step``,
an episode rolls with ``lax.scan`` (``rollout``), and parallel environments
``vmap`` across seeds / traces (``vec_rollout``). The NumPy ``PipelineEnv``
stays the reference implementation (``tests/test_vecenv.py`` pins step and
reward equivalence between the two) and the only backend for the
event-driven runtime path.

Scope, mirroring exactly what the PPO training path constructs:

- no external load predictor (predicted load = current load), matching the
  envs built by ``Session.train`` and ``benchmarks.common.trained_opd``;
- per-task variant tables are padded to the max variant count and indexed
  modulo the true per-task count, matching ``policy.action_to_config``.

The env itself is deterministic given its trace — all rollout stochasticity
comes from the policy's sampling key, which is per-environment so that
vmapped rollouts are permutation-invariant along the env axis.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np  # reprolint: ignore[RPL002] host-side table building only (tables_from_pipeline)

from repro.analysis import sanitize
from repro.core.mdp import (ADAPTATION_INTERVAL, COLD_START_FRACTION,
                            Pipeline, QoSWeights)
from repro.core.policy import apply_policy, sample_action


class PipelineTables(NamedTuple):
    """A ``Pipeline``'s static physics as arrays ([N, V_max] per-variant
    attributes, padded by repeating each task's last variant).

    The cluster topology is precomputed into arrays too: ``node_capacity`` /
    ``node_speed`` are **empty** ([0]) for a trivial (scalar-pool) topology —
    the empty shape is the static signal that ``step``/``observe`` take the
    legacy bit-for-bit code path and skip placement entirely."""
    accuracy: jax.Array      # [N, V]  v_n(z)
    cost: jax.Array          # [N, V]  c_n(z)
    resource: jax.Array      # [N, V]  w_n(z)
    alpha: jax.Array         # [N, V]  fixed per-batch latency (s)
    beta: jax.Array          # [N, V]  per-item latency slope (s)
    n_variants: jax.Array    # [N]     true |Z_n| before padding
    batch_choices: jax.Array  # [nb]   the b knob's value set (1, 2, 4, ...)
    f_max: jax.Array         # scalar
    b_max: jax.Array         # scalar
    w_max: jax.Array         # scalar W_max
    node_capacity: jax.Array  # [K]    chips per node ([0] -> scalar pool)
    node_speed: jax.Array    # [K]     per-node service-rate factor
    hop_latency: jax.Array   # scalar  s per adjacent-stage cross-node hop
    replica_slots: jax.Array  # [f_max] static replica-slot index (loop bound)
    batch_slots: jax.Array   # [b_max] static batch-slot index (shape carrier)

    @property
    def n_tasks(self) -> int:
        return self.accuracy.shape[0]

    @property
    def n_nodes(self) -> int:
        """Static node count; 0 means trivial topology (legacy physics)."""
        return self.node_capacity.shape[0]


class EnvState(NamedTuple):
    """One analytic environment: interval index + live configuration."""
    t: jax.Array             # scalar i32, adaptation-interval index
    z: jax.Array             # [N] i32 variant per task
    f: jax.Array             # [N] i32 replicas per task
    b: jax.Array             # [N] i32 batch size per task (actual value)


def tables_from_pipeline(pipe: Pipeline) -> PipelineTables:
    v_max = max(len(t.variants) for t in pipe.tasks)

    def tab(attr):
        rows = []
        for task in pipe.tasks:
            vals = [float(getattr(v, attr)) for v in task.variants]
            rows.append(vals + [vals[-1]] * (v_max - len(vals)))
        return jnp.asarray(np.asarray(rows, np.float32))

    if pipe.scalar_pool:
        node_capacity = jnp.zeros((0,), jnp.float32)
        node_speed = jnp.zeros((0,), jnp.float32)
        hop = jnp.float32(0.0)
    else:
        topo = pipe.topo
        node_capacity = jnp.asarray([n.capacity for n in topo.nodes],
                                    jnp.float32)
        node_speed = jnp.asarray([n.speed for n in topo.nodes], jnp.float32)
        hop = jnp.float32(topo.hop_latency)

    return PipelineTables(
        accuracy=tab("accuracy"), cost=tab("cost"), resource=tab("resource"),
        alpha=tab("alpha"), beta=tab("beta"),
        n_variants=jnp.asarray([len(t.variants) for t in pipe.tasks],
                               jnp.int32),
        batch_choices=jnp.asarray(pipe.batch_choices(), jnp.int32),
        f_max=jnp.float32(pipe.f_max), b_max=jnp.float32(pipe.b_max),
        w_max=jnp.float32(pipe.w_max),
        node_capacity=node_capacity, node_speed=node_speed, hop_latency=hop,
        replica_slots=jnp.arange(pipe.f_max, dtype=jnp.int32),
        batch_slots=jnp.arange(pipe.b_max, dtype=jnp.int32))


def init_state(tables: PipelineTables) -> EnvState:
    """The default configuration every episode starts from (z=0, f=1, b=1)."""
    n = tables.n_tasks
    return EnvState(t=jnp.int32(0), z=jnp.zeros(n, jnp.int32),
                    f=jnp.ones(n, jnp.int32), b=jnp.ones(n, jnp.int32))


def decode_action(tables: PipelineTables, action: jax.Array):
    """Policy head indices [3N] -> (z, f, b) arrays; the jnp twin of
    ``policy.action_to_config`` (modulo-clamped variants, f 1-based,
    batch looked up in the power-of-two choice set)."""
    z = action[0::3] % tables.n_variants
    f = action[1::3] + 1
    nb = tables.batch_choices.shape[0]
    b = tables.batch_choices[action[2::3] % nb]
    return z.astype(jnp.int32), f.astype(jnp.int32), b.astype(jnp.int32)


def _gather(table: jax.Array, z: jax.Array) -> jax.Array:
    """table [N, V], z [N] -> per-task values [N]."""
    return jnp.take_along_axis(table, z[:, None], axis=1)[:, 0]


class PlacementArrays(NamedTuple):
    """Result of the jnp first-fit scheduler: per-stage aggregates plus the
    per-slot node speeds the runtime twin's replica pools dispatch with."""
    speed_sum: jax.Array     # [N]    Σ node speed over the stage's replicas
    min_speed: jax.Array     # [N]    slowest node hosting a replica
    primary: jax.Array       # [N]    node with the most replicas (ties low)
    overflow: jax.Array      # scalar force-placed resource shortfall
    rem: jax.Array           # [K]    per-node remaining capacity
    slot_speed: jax.Array    # [N, R] node speed of replica slot r (1 if r>=f)


def _placement(tables: PipelineTables, z: jax.Array,
               f: jax.Array) -> PlacementArrays:
    """The jnp twin of ``cluster.topology``'s first-fit scheduler, taking
    identical discrete decisions (capacities and per-replica resources are
    integral chip counts, so every comparison is exact in float32).

    Unrolled over the static (n_tasks × f_max) replica slots; inactive slots
    (r >= f_n) are masked out. Replica slot ``r`` of stage ``i`` maps to the
    Python scheduler's ``Placement.nodes[i][r]`` — same assignment order, so
    ``slot_speed`` mirrors ``RuntimeStage.replica_speeds`` exactly."""
    res = _gather(tables.resource, z)             # [N]
    K = tables.n_nodes
    R = tables.replica_slots.shape[0]
    rem = tables.node_capacity
    speed = tables.node_speed
    overflow = jnp.float32(0.0)
    speed_sums, min_speeds, primaries, slot_rows = [], [], [], []
    for i in range(tables.n_tasks):
        w = res[i]
        s_sum = jnp.float32(0.0)
        s_min = jnp.float32(jnp.inf)
        counts = jnp.zeros(K, jnp.int32)
        slots = []
        for r in range(R):
            active = r < f[i]
            fits = rem >= w
            idx = jnp.where(jnp.any(fits), jnp.argmax(fits), jnp.argmax(rem))
            take = jnp.minimum(w, rem[idx])
            amt = jnp.where(active, jnp.float32(1.0), jnp.float32(0.0))
            rem = rem.at[idx].add(-take * amt)
            overflow = overflow + (w - take) * amt
            s_sum = s_sum + speed[idx] * amt
            s_min = jnp.where(active, jnp.minimum(s_min, speed[idx]), s_min)
            counts = counts.at[idx].add(active.astype(jnp.int32))
            slots.append(jnp.where(active, speed[idx], 1.0))
        speed_sums.append(s_sum)
        min_speeds.append(jnp.where(jnp.isfinite(s_min), s_min, 1.0))
        primaries.append(jnp.argmax(counts))
        slot_rows.append(jnp.stack(slots))
    return PlacementArrays(speed_sum=jnp.stack(speed_sums),
                           min_speed=jnp.stack(min_speeds),
                           primary=jnp.stack(primaries),
                           overflow=overflow, rem=rem,
                           slot_speed=jnp.stack(slot_rows))


def observe_cfg(tables: PipelineTables, z: jax.Array, f: jax.Array,
                b: jax.Array, load: jax.Array) -> jax.Array:
    """Eq. (5) observation [N * 9] (plus one per-node free-capacity fraction
    per task row on a heterogeneous topology) for configuration (z, f, b)
    under current load ``load`` (req/s); predicted load = current load (the
    training envs attach no external predictor). Shared by the analytic
    ``observe`` (load from the trace) and the runtime twin (measured load)."""
    fj, bj = f.astype(jnp.float32), b.astype(jnp.float32)
    res = _gather(tables.resource, z)
    usage = jnp.sum(res * fj)
    u = (tables.w_max - usage) / tables.w_max
    p = load / 100.0
    lat = _gather(tables.alpha, z) + _gather(tables.beta, z) * bj
    thr = fj * bj / lat
    n = tables.n_tasks
    rows = jnp.stack([
        jnp.full((n,), u), jnp.full((n,), p), jnp.full((n,), p),
        lat,
        thr / 100.0,
        z / jnp.maximum(1, tables.n_variants - 1),
        fj / tables.f_max,
        bj / tables.b_max,
        fj * _gather(tables.cost, z) / tables.w_max,
    ], axis=1)
    if tables.n_nodes:                 # node status columns (heterogeneous)
        pl = _placement(tables, z, f)
        node_free = pl.rem / tables.node_capacity
        rows = jnp.concatenate(
            [rows, jnp.tile(node_free[None, :], (n, 1))], axis=1)
    return rows.reshape(-1).astype(jnp.float32)


def observe(tables: PipelineTables, state: EnvState,
            trace: jax.Array) -> jax.Array:
    """Eq. (5) observation of an analytic env state: current load read from
    the trace at the last second of the previous interval."""
    s = state.t * ADAPTATION_INTERVAL
    cur = trace[jnp.maximum(0, s - 1)]
    return observe_cfg(tables, state.z, state.f, state.b, cur)


@partial(jax.jit, static_argnames=("weights",))
def step(tables: PipelineTables, state: EnvState, action: jax.Array,
         trace: jax.Array, weights: QoSWeights):
    """One adaptation interval: decode ``action`` (policy head indices
    [3N]), apply the configuration, score Eq. (1)-(4)/(7) on the trace
    window. Deterministic given the trace. Returns (state', obs', reward,
    metrics)."""
    w = weights
    z, f, b = decode_action(tables, action)
    bf = b.astype(jnp.float32)
    fb = f.astype(jnp.float32) * bf

    s0 = state.t * ADAPTATION_INTERVAL
    window = jax.lax.dynamic_slice(trace, (s0,), (ADAPTATION_INTERVAL,))
    demand = jnp.mean(window)

    switched = (z != state.z).astype(jnp.float32)
    cold = COLD_START_FRACTION * jnp.sum(switched) / tables.n_tasks

    acc = _gather(tables.accuracy, z)
    cost = _gather(tables.cost, z)
    res = _gather(tables.resource, z)
    lat = _gather(tables.alpha, z) + _gather(tables.beta, z) * b

    v_sum = jnp.sum(acc)
    c_sum = jnp.sum(cost * f)
    # stage_latency: batch-assembly wait + M/M/1-style congested service
    wait = jnp.minimum(fb / jnp.maximum(demand, 1e-6), 2.0)
    if tables.n_nodes == 0:            # scalar pool — legacy physics
        thr = fb / lat
        lat_eff = lat
        hop_total = jnp.float32(0.0)
        infeasible = jnp.sum(res * f) > tables.w_max
    else:                              # placement-aware physics
        pl = _placement(tables, z, f)
        thr = pl.speed_sum * bf / lat
        lat_eff = lat / pl.min_speed
        n_hops = jnp.sum((pl.primary[:-1] != pl.primary[1:])
                         .astype(jnp.float32))
        hop_total = tables.hop_latency * n_hops
        infeasible = pl.overflow > 0
    rho = demand / jnp.maximum(thr, 1e-9)
    congestion = 1.0 / jnp.maximum(1.0 - rho, 0.1)
    lat_total = jnp.sum(wait + lat_eff * congestion) + hop_total

    capacity = jnp.min(thr) * (1.0 - cold)
    excess = demand - capacity
    t_meas = jnp.minimum(demand, capacity)

    qos = (w.alpha * v_sum + w.beta * t_meas - lat_total
           - jnp.where(excess >= 0, w.gamma * excess, w.delta * (-excess)))
    reward = qos - w.beta_c * c_sum - w.gamma_b * jnp.max(b)
    reward = reward - 50.0 * infeasible

    new_state = EnvState(t=state.t + 1, z=z, f=f, b=b)
    metrics = {"qos": qos, "cost": c_sum, "latency": lat_total,
               "throughput": t_meas, "excess": excess, "demand": demand,
               "capacity": capacity, "infeasible": infeasible}
    return new_state, observe(tables, new_state, trace), reward, metrics


@sanitize.checked
def rollout(params, tables: PipelineTables, trace: jax.Array, key: jax.Array,
            *, n_steps: int, weights: QoSWeights, greedy: bool = False):
    """One on-policy episode via ``lax.scan``: sample action, step the env,
    collect the PPO trajectory. Uses the same ``sample_action`` as serving,
    so vectorized training and deployment share the policy path."""
    state0 = init_state(tables)
    obs0 = observe(tables, state0, trace)

    def one_step(carry, _):
        state, obs, k = carry
        k, sub = jax.random.split(k)
        action, logp, value = sample_action(params, obs, sub, greedy=greedy)
        state, obs_next, r, metrics = step(tables, state, action, trace,
                                           weights)
        out = {"states": obs, "actions": action, "logps": logp,
               "rewards": r, "values": value, **metrics}
        return (state, obs_next, k), out

    (_, obs_last, _), traj = jax.lax.scan(one_step, (state0, obs0, key),
                                          None, length=n_steps)
    _, last_value = apply_policy(params, obs_last[None])
    traj["last_value"] = last_value[0]
    return traj


@sanitize.checked
@partial(jax.jit, static_argnames=("n_steps", "weights", "greedy"))
def vec_rollout(params, tables: PipelineTables, traces: jax.Array,
                keys: jax.Array, *, n_steps: int, weights: QoSWeights,
                greedy: bool = False):
    """Parallel episodes: vmap ``rollout`` over (trace, key) pairs. Returns
    env-major arrays [num_envs, n_steps, ...] plus ``last_value``
    [num_envs]. Each env consumes only its own key and trace, so permuting
    the env axis permutes the outputs."""
    fn = partial(rollout, n_steps=n_steps, weights=weights, greedy=greedy)
    return jax.vmap(lambda tr, k: fn(params, tables, tr, k))(traces, keys)


@partial(jax.jit, static_argnames=("gamma", "lam"))
def gae_scan(rewards: jax.Array, values: jax.Array, last_value: jax.Array,
             *, gamma: float, lam: float):
    """Scan-based GAE over one episode [T]; the jnp twin of
    ``ppo.compute_gae``. Returns (advantages, returns)."""

    def back(carry, rv):
        gae, v_next = carry
        r, v = rv
        delta = r + gamma * v_next - v
        gae = delta + gamma * lam * gae
        return (gae, v), gae

    init = (jnp.zeros_like(last_value), last_value)
    _, adv = jax.lax.scan(back, init, (rewards, values), reverse=True)
    return adv, adv + values


@partial(jax.jit, static_argnames=("gamma", "lam"))
def vec_gae(rewards: jax.Array, values: jax.Array, last_values: jax.Array,
            *, gamma: float, lam: float):
    """Batched GAE: [num_envs, T] rewards/values, [num_envs] bootstrap."""
    fn = partial(gae_scan, gamma=gamma, lam=lam)
    return jax.vmap(lambda r, v, lv: fn(r, v, lv))(rewards, values,
                                                   last_values)
