"""Feature extraction module (paper §IV-C): node+pipeline state -> FC
dimensionality reduction -> residual blocks -> unified feature vector."""
from __future__ import annotations

from repro import nn

FEATURE_DIM = 128
N_BLOCKS = 3


def init_features(key, state_dim: int, *, dim: int = FEATURE_DIM,
                  n_blocks: int = N_BLOCKS):
    return nn.init_res_mlp(key, state_dim, dim, n_blocks)


def extract(params, state):
    """state [B, state_dim] -> features [B, FEATURE_DIM]."""
    return nn.res_mlp(params, state)
