"""The paper's problem model (§III): pipeline, metrics, QoS, objective.

A pipeline is a chain of tasks; each task n has a set of model variants Z_n.
A configuration assigns every task a (variant index z, replicas f, batch b).

Metrics (paper equations):
  Eq. (1)  V = Σ_n v_n(z_n)                      pipeline accuracy
  Eq. (2)  C = Σ_n f_n · c_n(z_n)                 cost (chips, was CPU cores)
  Eq. (3)  Q = α·V + β·T − L − γ·E⁺ / − δ·(−E)⁻   QoS
  Eq. (4)  max  Q − λ·C   s.t. bounds + Σ w_n(z_n)·f_n ≤ W_max
  Eq. (7)  r = Q − β_c·C − γ_b·B                  RL reward

The resource constraint has two regimes. With no explicit cluster topology
(``Pipeline.topology is None``, or a single unit-speed node) the cluster is
the paper's scalar pool: Σ w_n(z_n)·f_n ≤ W_max, and every formula below is
bit-for-bit the historical behaviour. With a heterogeneous
``cluster.topology.ClusterTopology``, feasibility and physics become
*placement-aware*: replicas are bin-packed onto nodes by the deterministic
first-fit scheduler, node speed factors scale each stage's service latency
and throughput, and adjacent stages whose primary nodes differ pay the
topology's cross-node hop latency.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:                       # core must not import cluster at
    from repro.cluster.topology import ClusterTopology, Placement  # runtime

ADAPTATION_INTERVAL = 10          # seconds between decisions (paper §VI-B)
COLD_START_FRACTION = 0.3         # capacity lost in the interval after a switch


@dataclass(frozen=True)
class ModelVariant:
    """One servable model variant for a pipeline task.

    latency(b) = alpha + beta * b   (seconds, batch-linear serving model)
    throughput at batch b with f replicas = f * b / latency(b)
    """
    name: str
    accuracy: float          # v_n(z)  in [0, 1]
    cost: float              # c_n(z)  chips per replica
    resource: float          # w_n(z)  resource units per replica (== cost here)
    alpha: float             # fixed per-batch latency (s)
    beta: float              # per-item latency slope (s)

    def latency(self, batch: int) -> float:
        return self.alpha + self.beta * batch

    def throughput(self, batch: int, replicas: int) -> float:
        return replicas * batch / self.latency(batch)


@dataclass(frozen=True)
class Task:
    name: str
    variants: tuple[ModelVariant, ...]


@dataclass(frozen=True)
class Pipeline:
    name: str
    tasks: tuple[Task, ...]
    f_max: int = 8
    b_max: int = 32
    w_max: float = 64.0      # total device resource capacity W_max
    # None = the legacy homogeneous scalar pool of capacity w_max
    topology: ClusterTopology | None = field(default=None)

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    @property
    def scalar_pool(self) -> bool:
        """True when resources behave as the paper's single scalar pool
        (no topology, or a trivial single-node unit-speed one)."""
        return self.topology is None or self.topology.trivial

    @property
    def topo(self) -> ClusterTopology:
        """The cluster topology, materializing the implicit homogeneous
        single-node one when none was declared."""
        if self.topology is not None:
            return self.topology
        from repro.cluster.topology import ClusterTopology
        return ClusterTopology.homogeneous(self.w_max)

    def batch_choices(self) -> list[int]:
        out, b = [], 1
        while b <= self.b_max:
            out.append(b)
            b *= 2
        return out


@dataclass(frozen=True)
class QoSWeights:
    """Eq. (3)/(4)/(7) weighting parameters."""
    alpha: float = 4.0       # accuracy weight
    beta: float = 0.05       # (measured) throughput weight
    gamma: float = 0.08      # unmet-demand penalty (E >= 0)
    delta: float = 0.005     # spare-capacity penalty (E < 0)
    lam: float = 0.12        # cost weight in the objective (Eq. 4)
    beta_c: float = 0.12     # cost weight in the reward (Eq. 7)
    gamma_b: float = 0.02    # batch-size penalty in the reward (Eq. 7)


@dataclass(frozen=True)
class Config:
    """One decision a_t: per-task (variant z, replicas f, batch b)."""
    z: tuple[int, ...]
    f: tuple[int, ...]
    b: tuple[int, ...]

    def as_array(self) -> np.ndarray:
        return np.array([self.z, self.f, self.b], dtype=np.int64).T   # [N, 3]


def stage_latency(var: ModelVariant, b: int, f: int, demand: float, *,
                  speed_sum: float | None = None,
                  min_speed: float = 1.0) -> float:
    """End-to-end stage latency: batch-assembly wait (time to fill a batch of
    b at arrival rate demand/f per replica) + queue-aware service time
    (M/M/1-style 1/(1-ρ) inflation as utilisation approaches capacity).

    Placement-aware form: ``speed_sum`` (Σ node speed over the stage's
    replicas) replaces the plain replica count in the throughput term, and
    ``min_speed`` (the slowest node hosting a replica) stretches the service
    time — the slowest device dominates the tail. The defaults reproduce the
    homogeneous arithmetic exactly."""
    service = var.latency(b) / min_speed
    wait = min(b * f / max(demand, 1e-6), 2.0)
    if speed_sum is None:
        thr = var.throughput(b, f)
    else:
        thr = speed_sum * b / var.latency(b)
    rho = demand / max(thr, 1e-9)
    congestion = 1.0 / max(1.0 - rho, 0.1)
    return wait + service * congestion


def placement_for(pipe: Pipeline, cfg: Config) -> Placement:
    """The deterministic placement of ``cfg``'s replicas on the pipeline's
    cluster topology (memoized per (topology, resources, replicas))."""
    res = tuple(task.variants[cfg.z[n]].resource
                for n, task in enumerate(pipe.tasks))
    return pipe.topo.place(res, cfg.f)


def pipeline_metrics(pipe: Pipeline, cfg: Config, demand: float,
                     *, cold_frac: float = 0.0):
    """(V, C, T_meas, L, E, capacity) under ``demand`` req/s.

    capacity = min stage capacity (paper: min throughput across tasks);
    T_meas   = measured pipeline throughput = min(capacity, demand) — what a
               Prometheus monitor reports; used in the QoS (Eq. 3) T term;
    E        = demand - capacity (positive -> unmet load, negative -> spare);
    cold_frac degrades capacity (variant-switch cold start).

    On a heterogeneous topology the stage physics are placement-aware: node
    speed factors scale service latency and throughput, and each adjacent
    stage pair whose primary nodes differ adds ``topo.hop_latency`` to L.
    """
    V = C = L = 0.0
    capacity = float("inf")
    if pipe.scalar_pool:
        for n, task in enumerate(pipe.tasks):
            var = task.variants[cfg.z[n]]
            f, b = cfg.f[n], cfg.b[n]
            V += var.accuracy
            C += f * var.cost
            L += stage_latency(var, b, f, demand)
            capacity = min(capacity, var.throughput(b, f))
    else:
        pl = placement_for(pipe, cfg)
        for n, task in enumerate(pipe.tasks):
            var = task.variants[cfg.z[n]]
            f, b = cfg.f[n], cfg.b[n]
            V += var.accuracy
            C += f * var.cost
            L += stage_latency(var, b, f, demand,
                               speed_sum=pl.stage_speed_sum[n],
                               min_speed=pl.stage_min_speed[n])
            capacity = min(capacity,
                           pl.stage_speed_sum[n] * b / var.latency(b))
        L += pipe.topo.hop_latency * pl.n_hops
    capacity *= (1.0 - cold_frac)
    E = demand - capacity
    T_meas = min(demand, capacity)
    return V, C, T_meas, L, E, capacity


def analytic_pipeline_latency(pipe: Pipeline, cfg: Config,
                              demand: float) -> float:
    """Closed-form end-to-end latency of the pipeline (the L term of
    ``pipeline_metrics`` alone) — the runtime env's smooth fallback when an
    interval completes no requests."""
    if pipe.scalar_pool:
        return sum(stage_latency(task.variants[cfg.z[n]], cfg.b[n], cfg.f[n],
                                 demand)
                   for n, task in enumerate(pipe.tasks))
    pl = placement_for(pipe, cfg)
    L = sum(stage_latency(task.variants[cfg.z[n]], cfg.b[n], cfg.f[n], demand,
                          speed_sum=pl.stage_speed_sum[n],
                          min_speed=pl.stage_min_speed[n])
            for n, task in enumerate(pipe.tasks))
    return L + pipe.topo.hop_latency * pl.n_hops


def resource_usage(pipe: Pipeline, cfg: Config) -> float:
    return sum(task.variants[cfg.z[n]].resource * cfg.f[n]
               for n, task in enumerate(pipe.tasks))


def resources_feasible(pipe: Pipeline, cfg: Config) -> bool:
    """The resource constraint alone: scalar pool -> Σ w·f ≤ W_max;
    heterogeneous topology -> every replica found a node (no overflow)."""
    if pipe.scalar_pool:
        return resource_usage(pipe, cfg) <= pipe.w_max
    return placement_for(pipe, cfg).feasible


def feasible(pipe: Pipeline, cfg: Config) -> bool:
    if not resources_feasible(pipe, cfg):
        return False
    for n in range(pipe.n_tasks):
        if not (0 <= cfg.z[n] < len(pipe.tasks[n].variants)):
            return False
        if not (1 <= cfg.f[n] <= pipe.f_max):
            return False
        if not (1 <= cfg.b[n] <= pipe.b_max):
            return False
    return True


def score_measurements(V: float, C: float, T: float, L: float, E: float,
                       w: QoSWeights, *, max_batch: int) -> dict:
    """Eq. (3)/(4)/(7) scoring of one interval's metrics.

    The metrics may come from the analytic model (``pipeline_metrics``) or
    from measured telemetry of the event-driven runtime — the QoS, reward and
    objective formulas are shared so env-sim and runtime-sim agree.
    """
    q = w.alpha * V + w.beta * T - L - (w.gamma * E if E >= 0
                                        else w.delta * (-E))
    r = q - w.beta_c * C - w.gamma_b * max_batch
    return {"V": V, "C": C, "T": T, "L": L, "E": E,
            "qos": q, "reward": r, "objective": q - w.lam * C}


def accuracy_and_cost(pipe: Pipeline, cfg: Config) -> tuple[float, float]:
    """Eq. (1)/(2): pipeline accuracy V and chip cost C of a configuration."""
    V = sum(task.variants[cfg.z[n]].accuracy for n, task in enumerate(pipe.tasks))
    C = sum(task.variants[cfg.z[n]].cost * cfg.f[n]
            for n, task in enumerate(pipe.tasks))
    return V, C


def evaluate(pipe: Pipeline, cfg: Config, demand: float, w: QoSWeights,
             *, cold_frac: float = 0.0) -> dict:
    """All paper metrics for one interval: Eq. (1)-(4) and (7)."""
    V, C, T, L, E, capacity = pipeline_metrics(pipe, cfg, demand,
                                               cold_frac=cold_frac)
    out = score_measurements(V, C, T, L, E, w, max_batch=max(cfg.b))
    out["capacity"] = capacity
    return out


def qos(pipe: Pipeline, cfg: Config, demand: float, w: QoSWeights) -> float:
    """Eq. (3)."""
    return evaluate(pipe, cfg, demand, w)["qos"]


def objective(pipe: Pipeline, cfg: Config, demand: float, w: QoSWeights) -> float:
    """Eq. (4):  Q − λ·C."""
    return evaluate(pipe, cfg, demand, w)["objective"]


def reward(pipe: Pipeline, cfg: Config, demand: float, w: QoSWeights) -> float:
    """Eq. (7):  Q − β_c·C − γ_b·B  (B = max batch across tasks)."""
    return evaluate(pipe, cfg, demand, w)["reward"]
