"""Algorithm 1 — the online OPD loop: predict load, observe state, select
action, measure decision time d_t, apply configuration, collect reward.
Outputs the per-step telemetry and cumulative decision time H = Σ d_t.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mdp import Config, Pipeline
from repro.core.policy import action_to_config, sample_action


class OPDPolicy:
    """Deployable policy wrapper: (env) -> Config, measuring decision time."""

    def __init__(self, pipe: Pipeline, params, *, greedy: bool = True, seed: int = 0):
        self.pipe = pipe
        self.params = params
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.decision_times: list[float] = []
        # warm the jit cache so measured decision time is steady-state
        self._warm = False

    def __call__(self, env) -> Config:
        s = jnp.asarray(env._observe())
        if not self._warm:
            sample_action(self.params, s, self.key, greedy=self.greedy)
            self._warm = True
        t0 = time.perf_counter()
        self.key, sub = jax.random.split(self.key)
        a, _, _ = sample_action(self.params, s, sub, greedy=self.greedy)
        a = np.asarray(jax.block_until_ready(a))
        self.decision_times.append(time.perf_counter() - t0)
        return action_to_config(self.pipe, a)


def run_episode(env, policy) -> dict:
    """Run one workload cycle under ``policy`` (any (env)->Config callable).
    Returns per-step arrays: reward, qos, cost, latency, throughput, excess,
    and cumulative decision time H (if the policy records it)."""
    env.reset()
    if hasattr(policy, "decision_times"):
        # H must cover THIS episode only — a reused policy object would
        # otherwise report cumulative time across episodes
        policy.decision_times = []
    out = {k: [] for k in ("reward", "qos", "cost", "latency", "throughput",
                           "excess", "demand")}
    done = False
    while not done:
        cfg = policy(env)
        _, r, done, info = env.step(cfg)
        out["reward"].append(r)
        for k in ("qos", "cost", "latency", "throughput", "excess", "demand"):
            out[k].append(info[k])
    result = {k: np.asarray(v) for k, v in out.items()}
    if hasattr(policy, "decision_times"):
        result["decision_time_total"] = float(np.sum(policy.decision_times))
        result["decision_times"] = np.asarray(policy.decision_times)
    return result
