"""Algorithm 1 — the online OPD loop: predict load, observe state, select
action, measure decision time d_t, apply configuration, collect reward.
Outputs the per-step telemetry and cumulative decision time H = Σ d_t.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import ControllerBase, Observation
from repro.core.controller import decide as _decide
from repro.core.mdp import Config, Pipeline
from repro.core.policy import action_to_config, sample_action


class OPDPolicy(ControllerBase):
    """Deployable policy wrapper implementing the Controller protocol:
    ``decide(obs) -> Config``, measuring steady-state decision time."""

    def __init__(self, pipe: Pipeline, params, *, greedy: bool = True, seed: int = 0):
        self.pipe = pipe
        self.params = params
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.decision_times: list[float] = []
        # warm the jit cache so measured decision time is steady-state
        self._warm = False

    def warmup(self, obs: Observation) -> None:
        """Burn the jit warmup forward pass on its own throwaway subkey —
        never timed, never reused, so the first real decision's randomness
        is independent of the warmup. Idempotent; ``decide`` calls it
        lazily, so the key evolution is identical either way."""
        if self._warm:
            return
        self.key, warm_key = jax.random.split(self.key)
        a_w, _, _ = sample_action(self.params, jnp.asarray(obs.state),
                                  warm_key, greedy=self.greedy)
        jax.block_until_ready(a_w)
        self._warm = True

    def decide(self, obs: Observation) -> Config:
        s = jnp.asarray(obs.state)
        self.warmup(obs)
        t0 = time.perf_counter()
        self.key, sub = jax.random.split(self.key)
        a, _, _ = sample_action(self.params, s, sub, greedy=self.greedy)
        a = np.asarray(jax.block_until_ready(a))
        self.decision_times.append(time.perf_counter() - t0)
        return action_to_config(self.pipe, a)


def run_episodes_vectorized(pipe: Pipeline, params, traces, *, weights=None,
                            greedy: bool = True, seed: int = 0) -> dict:
    """Batch policy evaluation on the analytic dynamics: one episode per
    trace row [B, seconds] via the pure-JAX engine (``core.vecenv``),
    returning per-episode per-step arrays [B, T] (reward, qos, cost,
    latency, throughput, excess, demand). Greedy decode by default, so the
    result is deterministic in ``params`` and ``traces``."""
    from repro.core.mdp import ADAPTATION_INTERVAL, QoSWeights
    from repro.core.vecenv import tables_from_pipeline, vec_rollout

    traces = np.asarray(traces, np.float32)
    tables = tables_from_pipeline(pipe)
    keys = jax.vmap(lambda s: jax.random.fold_in(jax.random.PRNGKey(seed),
                                                 s))(jnp.arange(len(traces)))
    out = vec_rollout(params, tables, jnp.asarray(traces), keys,
                      n_steps=traces.shape[1] // ADAPTATION_INTERVAL,
                      weights=weights or QoSWeights(), greedy=greedy)
    keep = ("rewards", "qos", "cost", "latency", "throughput", "excess",
            "demand", "actions")
    return {k: np.asarray(out[k]) for k in keep}


def run_episode(env, policy) -> dict:
    """Run one workload cycle under ``policy`` (a Controller or any legacy
    (env)->Config callable). Returns per-step arrays: reward, qos, cost,
    latency, throughput, excess, and cumulative decision time H (if the
    policy records it)."""
    env.reset()
    if hasattr(policy, "decision_times"):
        # H must cover THIS episode only — a reused policy object would
        # otherwise report cumulative time across episodes
        policy.decision_times = []
    out = {k: [] for k in ("reward", "qos", "cost", "latency", "throughput",
                           "excess", "demand")}
    done = False
    while not done:
        cfg = _decide(policy, env)
        _, r, done, info = env.step(cfg)
        out["reward"].append(r)
        for k in ("qos", "cost", "latency", "throughput", "excess", "demand"):
            out[k].append(info[k])
    result = {k: np.asarray(v) for k, v in out.items()}
    if hasattr(policy, "decision_times"):
        result["decision_time_total"] = float(np.sum(policy.decision_times))
        result["decision_times"] = np.asarray(policy.decision_times)
    return result
