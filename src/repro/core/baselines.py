"""Baseline configuration policies (paper §VI-A): Random, Greedy, IPA.

Each baseline implements the Controller protocol ``decide(obs) -> Config``,
deciding from the public :class:`~repro.core.controller.Observation`
(predicted load, live config) and the pipeline spec — the same interface the
OPD agent uses. Legacy ``policy(env)`` call sites keep working through the
``ControllerBase`` shim.
"""
from __future__ import annotations

import itertools
import time

import numpy as np

from repro.core.controller import ControllerBase, Observation
from repro.core.mdp import (Config, Pipeline, QoSWeights, feasible,
                            pipeline_metrics)


class RandomPolicy(ControllerBase):
    """Uniformly random feasible configuration."""

    def __init__(self, pipe: Pipeline, seed: int = 0):
        self.pipe = pipe
        self.rng = np.random.default_rng(seed)

    def decide(self, obs: Observation) -> Config:
        pipe = self.pipe
        bc = pipe.batch_choices()
        for _ in range(64):
            cfg = Config(
                z=tuple(self.rng.integers(0, len(t.variants)) for t in pipe.tasks),
                f=tuple(self.rng.integers(1, pipe.f_max + 1) for _ in pipe.tasks),
                b=tuple(self.rng.choice(bc) for _ in pipe.tasks),
            )
            if feasible(pipe, cfg):
                return cfg
        return Config(z=tuple(0 for _ in pipe.tasks),
                      f=tuple(1 for _ in pipe.tasks),
                      b=tuple(1 for _ in pipe.tasks))


class GreedyPolicy(ControllerBase):
    """Minimise cost while adhering to resource constraints: cheapest variant
    per stage, minimal replicas/batch to cover the predicted demand."""

    def __init__(self, pipe: Pipeline):
        self.pipe = pipe

    def decide(self, obs: Observation) -> Config:
        pipe = self.pipe
        demand = obs.predicted_load
        bc = pipe.batch_choices()
        z, f, b = [], [], []
        cursor = pipe.topo.cursor()     # placement-aware remaining capacity
        for task in pipe.tasks:
            # cheapest first, fastest (smallest beta) as tie-break — greedy is
            # quality-blind, exactly the paper's "minimise costs" baseline
            zi = int(np.lexsort(([v.beta for v in task.variants],
                                 [v.cost for v in task.variants]))[0])
            var = task.variants[zi]
            best = (1, bc[0])
            found = False
            for fi in range(1, pipe.f_max + 1):
                if not cursor.can_place(var.resource, fi):
                    break
                for bi in bc:
                    if var.throughput(bi, fi) >= demand:
                        best = (fi, bi)
                        found = True
                        break
                if found:
                    break
            fi, bi = best
            cursor.place(var.resource, fi)
            z.append(zi)
            f.append(fi)
            b.append(bi)
        return Config(z=tuple(z), f=tuple(f), b=tuple(b))


class IPAPolicy(ControllerBase):
    """IPA-style solver [Ghafouri et al.]: enumerate variant combinations
    across stages (product space — decision time grows with pipeline
    complexity), solving replicas/batch per stage to meet demand; maximise
    accuracy-first objective. Extended (as in the paper) to respect the
    resource capacity W_max."""

    def __init__(self, pipe: Pipeline, weights: QoSWeights | None = None,
                 accuracy_weight: float = 10.0):
        self.pipe = pipe
        self.w = weights or QoSWeights()
        self.acc_w = accuracy_weight
        self.decision_times: list[float] = []

    def _solve_stage(self, var, demand, cursor, reserve):
        """(f, b) meeting demand for a fixed variant, minimising stage
        latency within the cluster's remaining placeable capacity (leaving
        ``reserve`` for later stages) — IPA overprovisions for QoS headroom
        (the paper: "the most expensive, delivers the highest QoS"), or
        None if the variant cannot meet demand at all."""
        from repro.core.mdp import stage_latency
        best = None
        for fi in range(1, self.pipe.f_max + 1):
            if not cursor.can_place(var.resource, fi, reserve=reserve):
                break
            for bi in self.pipe.batch_choices():
                if var.throughput(bi, fi) >= demand:
                    lat = stage_latency(var, bi, fi, demand)
                    if best is None or lat < best[0]:
                        best = (lat, fi, bi)
        return None if best is None else (best[1], best[2])

    def decide(self, obs: Observation) -> Config:
        t0 = time.perf_counter()
        pipe = self.pipe
        demand = obs.predicted_load
        best_cfg, best_score = None, -np.inf
        variant_ranges = [range(len(t.variants)) for t in pipe.tasks]
        for zs in itertools.product(*variant_ranges):
            f, b, ok = [], [], True
            cursor = pipe.topo.cursor()
            for n, task in enumerate(pipe.tasks):
                var = task.variants[zs[n]]
                # leave an even budget share for the remaining stages
                remaining = pipe.n_tasks - n - 1
                reserve = remaining * min(v.resource for t in pipe.tasks[n + 1:]
                                          for v in t.variants) if remaining else 0.0
                sol = self._solve_stage(var, demand, cursor, reserve)
                if sol is None:
                    ok = False
                    break
                cursor.place(var.resource, sol[0])
                f.append(sol[0])
                b.append(sol[1])
            if not ok:
                continue
            cfg = Config(z=tuple(zs), f=tuple(f), b=tuple(b))
            V, C, T, L, E, _ = pipeline_metrics(pipe, cfg, demand)
            score = self.acc_w * V - self.w.lam * C - L
            if score > best_score:
                best_cfg, best_score = cfg, score
        self.decision_times.append(time.perf_counter() - t0)
        if best_cfg is None:
            return GreedyPolicy(pipe).decide(obs)
        return best_cfg
