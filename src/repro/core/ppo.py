"""PPO with clipped surrogate objective (Eq. 11/12) + expert-guided episodes
(Algorithm 2). Optimiser: mini-batch Adam (paper: "Optimize the network by
mini-batch SGD with Adam optimizer").

Rollout collection has two engines:

- legacy loop: one NumPy ``PipelineEnv``/``RuntimeEnv`` stepped per Python
  iteration — the reference path, and the only one that can drive the
  expert (host-side coordinate descent);
- vectorized analytic (``num_envs > 1``): the pure-JAX ``core.vecenv``
  engine rolls ``num_envs`` analytic environments per episode in one jitted
  scan-over-vmap call, with scan-based GAE (``benchmarks/train_throughput``
  measures the speedup and CI gates it);
- vectorized runtime (``vec_runtime`` arrivals factory): the
  ``core.runtime_vec`` discrete-event twin rolls closed-loop episodes on
  the *runtime* dynamics — queues, batch timeouts, cold starts — entirely
  inside one jitted call, never constructing a per-env ``RuntimeEnv``
  (``benchmarks/runtime_train_throughput`` measures the speedup).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np  # reprolint: ignore[RPL002] host-side batch assembly/logging only, never under jit

from repro.core import runtime_vec
from repro.core.expert import ExpertPolicy
from repro.core.mdp import ADAPTATION_INTERVAL, Pipeline, QoSWeights
from repro.core.policy import (action_to_config, config_to_action, head_sizes,
                               init_policy, log_prob_entropy, sample_action)
from repro.core.vecenv import tables_from_pipeline, vec_gae, vec_rollout
from repro.train import adamw_init, adamw_update, clip_by_global_norm

# vectorized env seeds start here so they never collide with the small
# integer seeds the legacy/expert episodes hand to make_env directly
VEC_SEED_BASE = 100_000


@dataclass(frozen=True)
class PPOConfig:
    lr: float = 3e-4
    clip_eps: float = 0.2        # ε in Eq. (12)
    c1: float = 0.5              # value-loss coefficient (Eq. 11)
    c2: float = 0.01             # entropy-bonus coefficient (Eq. 11)
    gamma: float = 0.99
    gae_lambda: float = 0.95
    epochs: int = 4
    minibatch: int = 64
    expert_freq: int = 4         # every f-th episode uses expert actions (Alg. 2)
    reward_scale: float = 0.05   # keeps value targets O(1) for stable VF learning
    # Alg. 2 keeps a replay memory D of expert transitions; we distil it into
    # the policy with a behaviour-cloning auxiliary loss each update.
    bc_coef: float = 0.3
    expert_buffer: int = 8192    # max expert (s, a) pairs retained in D


def compute_gae(rewards, values, last_value, *, gamma: float, lam: float):
    """Generalised advantage estimation over one episode."""
    T = len(rewards)
    adv = np.zeros(T, dtype=np.float32)
    gae = 0.0
    for t in reversed(range(T)):
        v_next = last_value if t == T - 1 else values[t + 1]
        delta = rewards[t] + gamma * v_next - values[t]
        gae = delta + gamma * lam * gae
        adv[t] = gae
    returns = adv + values
    return adv, returns


@partial(jax.jit, static_argnames=("clip_eps", "c1", "c2", "lr"))
def ppo_minibatch_update(params, opt, states, actions, old_logp, adv, returns,
                         bc_states, bc_actions, bc_coef,
                         *, clip_eps: float, c1: float, c2: float, lr: float):
    def loss_fn(p):
        logp, ent, value = log_prob_entropy(p, states, actions)
        ratio = jnp.exp(logp - old_logp)
        clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
        l_clip = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
        l_vf = jnp.mean((value - returns) ** 2)
        l_ent = jnp.mean(ent)
        # behaviour cloning on the expert replay memory D (Alg. 2)
        bc_logp, _, _ = log_prob_entropy(p, bc_states, bc_actions)
        l_bc = -jnp.mean(bc_logp)
        loss = l_clip + c1 * l_vf - c2 * l_ent + bc_coef * l_bc
        return loss, (l_clip, l_vf, l_ent)

    (loss, (l_clip, l_vf, l_ent)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params)
    grads, _ = clip_by_global_norm(grads, 0.5)
    params, opt = adamw_update(params, grads, opt, lr=lr, weight_decay=0.0)
    return params, opt, loss, l_clip, l_vf, l_ent


class OPDTrainer:
    """Algorithm 2: expert-guided PPO training of the OPD policy."""

    def __init__(self, pipe: Pipeline, make_env, *, ppo: PPOConfig | None = None,
                 weights: QoSWeights | None = None, seed: int = 0,
                 num_envs: int = 1, vec_runtime=None):
        self.pipe = pipe
        self.make_env = make_env
        self.ppo = ppo or PPOConfig()
        self.expert = ExpertPolicy(pipe, weights)
        self.sizes = head_sizes(pipe)
        env = make_env(0)
        self.params = init_policy(jax.random.PRNGKey(seed), env.state_dim,
                                  self.sizes)
        self.opt = adamw_init(self.params)
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed + 1)
        self.history = {"reward": [], "loss": [], "value_loss": [],
                        "policy_loss": [], "entropy": [], "expert": []}
        # replay memory D of expert transitions (Algorithm 2)
        self.expert_states = np.zeros((0, env.state_dim), np.float32)
        self.expert_actions = np.zeros((0, len(self.sizes)), np.int32)
        # vectorized rollout engines: core.vecenv for analytic envs without
        # an external predictor, core.runtime_vec (the discrete-event twin)
        # when a ``vec_runtime`` arrivals factory (seed -> ArrivalProcess)
        # is supplied — expert episodes always keep the legacy per-step loop
        self.num_envs = max(1, int(num_envs))
        self._vec_runtime = vec_runtime
        self._vec_ok = (self.num_envs > 1 and hasattr(env, "trace")
                        and getattr(env, "predictor", None) is None)
        self._tables = (tables_from_pipeline(pipe)
                        if self._vec_ok or vec_runtime is not None else None)
        self._weights = getattr(env, "w", None) or QoSWeights()
        if vec_runtime is not None:
            self._rt_horizon = int(getattr(env, "horizon", 120))
            self._rt_max_wait = float(
                getattr(env, "max_wait", runtime_vec.DEFAULT_MAX_WAIT))

    def _rollout(self, env, use_expert: bool):
        states, actions, logps, rewards, values = [], [], [], [], []
        s = env.reset()
        done = False
        while not done:
            s_j = jnp.asarray(s)
            self.key, sub = jax.random.split(self.key)
            if use_expert:
                cfg = self.expert.decide(env.observe())
                a = config_to_action(self.pipe, cfg)
                logp, _, v = log_prob_entropy(
                    self.params, s_j[None], jnp.asarray(a)[None])
                logp, v = float(logp[0]), float(v[0])
            else:
                a_j, logp_j, v_j = sample_action(self.params, s_j, sub)
                a = np.asarray(a_j)
                cfg = action_to_config(self.pipe, a)
                logp, v = float(logp_j), float(v_j)
            s_next, r, done, info = env.step(cfg)
            states.append(s)
            actions.append(a)
            logps.append(logp)
            rewards.append(r)
            values.append(v)
            s = s_next
        _, _, last_v = log_prob_entropy(
            self.params, jnp.asarray(s)[None],
            jnp.asarray(actions[-1])[None])
        return (np.asarray(states, np.float32), np.asarray(actions, np.int32),
                np.asarray(logps, np.float32), np.asarray(rewards, np.float32),
                np.asarray(values, np.float32), float(last_v[0]))

    def _env_keys(self, s0: int):
        """Per-env PRNG keys folded from distinct seeds ``s0 + i``."""
        self.key, ep_key = jax.random.split(self.key)
        seeds = jnp.arange(s0, s0 + self.num_envs)
        return jax.vmap(lambda s: jax.random.fold_in(ep_key, s))(seeds)

    def _finish_vec(self, traj):
        """Batched GAE + flatten a [num_envs, T, ...] trajectory to the
        [num_envs * T] transition arrays ``_update`` consumes."""
        cfg = self.ppo
        adv, returns = vec_gae(traj["rewards"] * cfg.reward_scale,
                               traj["values"], traj["last_value"],
                               gamma=cfg.gamma, lam=cfg.gae_lambda)
        def flat(a):
            return np.asarray(a).reshape(-1, *a.shape[2:])

        return (flat(traj["states"]).astype(np.float32),
                flat(traj["actions"]).astype(np.int32),
                flat(traj["logps"]).astype(np.float32),
                np.asarray(traj["rewards"], np.float32),
                flat(adv).astype(np.float32),
                flat(returns).astype(np.float32))

    def _rollout_vec(self, base_seed: int):
        """Collect ``num_envs`` parallel episodes with the pure-JAX engine:
        one jitted scan-over-vmap call. Env seeds are ``VEC_SEED_BASE +
        base_seed * num_envs + i`` — distinct traces per env, disjoint
        across episodes AND from the small legacy/expert episode seeds, so
        the expert replay memory never replays an on-policy trace. Returns
        flattened [num_envs * T] trajectory arrays + batched GAE."""
        s0 = VEC_SEED_BASE + base_seed * self.num_envs
        envs = [self.make_env(s0 + i) for i in range(self.num_envs)]
        n_steps = envs[0].n_steps
        assert all(e.n_steps == n_steps for e in envs), \
            "vectorized rollout needs equal-length traces"
        traces = jnp.asarray(np.stack([e.trace for e in envs]), jnp.float32)
        traj = vec_rollout(self.params, self._tables, traces,
                           self._env_keys(s0), n_steps=n_steps,
                           weights=self._weights)
        return self._finish_vec(traj)

    def _rollout_vec_runtime(self, base_seed: int):
        """Collect ``num_envs`` closed-loop episodes on the discrete-event
        runtime twin (``core.runtime_vec``) in one jitted call. Only the
        host-side arrival arrays are materialised per env — no per-env
        ``RuntimeEnv``/``ServingRuntime`` objects are ever constructed.
        Same seed discipline as ``_rollout_vec``."""
        s0 = VEC_SEED_BASE + base_seed * self.num_envs
        eps = runtime_vec.stack_episodes([
            runtime_vec.episode_arrivals(self._vec_runtime(s0 + i),
                                         self._rt_horizon)
            for i in range(self.num_envs)])
        traj = runtime_vec.vec_rollout(
            self.params, self._tables, eps, self._env_keys(s0),
            n_steps=max(1, self._rt_horizon // ADAPTATION_INTERVAL),
            weights=self._weights, max_wait=self._rt_max_wait)
        return self._finish_vec(traj)

    def _update(self, states, actions, logps, adv, returns):
        """Mini-batch Adam epochs over one batch of transitions (Eq. 11)."""
        cfg = self.ppo
        T = len(states)
        losses, pls, vls, ents = [], [], [], []
        for _ in range(cfg.epochs):
            idx = self.rng.permutation(T)
            for s0 in range(0, T, cfg.minibatch):
                sel = idx[s0:s0 + cfg.minibatch]
                # sample a fixed-size BC batch from D (dummy + coef 0 until
                # the first expert episode fills it)
                if len(self.expert_states):
                    bsel = self.rng.integers(0, len(self.expert_states),
                                             size=cfg.minibatch)
                    bc_s = self.expert_states[bsel]
                    bc_a = self.expert_actions[bsel]
                    bc_c = cfg.bc_coef
                else:
                    bc_s = states[np.zeros(cfg.minibatch, np.int64)]
                    bc_a = actions[np.zeros(cfg.minibatch, np.int64)]
                    bc_c = 0.0
                self.params, self.opt, loss, l_clip, l_vf, l_ent = \
                    ppo_minibatch_update(
                        self.params, self.opt,
                        jnp.asarray(states[sel]), jnp.asarray(actions[sel]),
                        jnp.asarray(logps[sel]), jnp.asarray(adv[sel]),
                        jnp.asarray(returns[sel]),
                        jnp.asarray(bc_s), jnp.asarray(bc_a),
                        jnp.float32(bc_c),
                        clip_eps=cfg.clip_eps, c1=cfg.c1, c2=cfg.c2, lr=cfg.lr)
                losses.append(float(loss))
                pls.append(float(l_clip))
                vls.append(float(l_vf))
                ents.append(float(l_ent))
        return losses, pls, vls, ents

    def train_episode(self, episode_idx: int, *, env_seed: int | None = None):
        cfg = self.ppo
        use_expert = cfg.expert_freq > 0 and episode_idx % cfg.expert_freq == 0
        base = env_seed if env_seed is not None else episode_idx

        if self._vec_runtime is not None and not use_expert:
            states, actions, logps, rewards, adv, returns = \
                self._rollout_vec_runtime(base)
        elif self._vec_ok and not use_expert:
            states, actions, logps, rewards, adv, returns = \
                self._rollout_vec(base)
        else:
            # expert episodes stay on the legacy loop: the expert is a
            # host-side coordinate-descent search (Alg. 2)
            env = self.make_env(base)
            states, actions, logps, rewards, values, last_v = self._rollout(
                env, use_expert)
            adv, returns = compute_gae(rewards * cfg.reward_scale, values,
                                       last_v, gamma=cfg.gamma,
                                       lam=cfg.gae_lambda)
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)

        if use_expert:          # store in replay memory D (Alg. 2)
            self.expert_states = np.concatenate(
                [self.expert_states, states])[-cfg.expert_buffer:]
            self.expert_actions = np.concatenate(
                [self.expert_actions, actions])[-cfg.expert_buffer:]

        losses, pls, vls, ents = self._update(states, actions, logps, adv,
                                              returns)

        self.history["reward"].append(float(rewards.mean()))
        self.history["loss"].append(float(np.mean(losses)))
        self.history["policy_loss"].append(float(np.mean(pls)))
        self.history["value_loss"].append(float(np.mean(vls)))
        self.history["entropy"].append(float(np.mean(ents)))
        self.history["expert"].append(bool(use_expert))
        return self.history

    def train(self, n_episodes: int, *, log=None):
        for e in range(1, n_episodes + 1):
            self.train_episode(e)
            if log:
                log(f"episode {e}: reward={self.history['reward'][-1]:.3f} "
                    f"loss={self.history['loss'][-1]:.4f} "
                    f"vloss={self.history['value_loss'][-1]:.4f}"
                    + (" [expert]" if self.history["expert"][-1] else ""))
        return self.history
