"""PPO with clipped surrogate objective (Eq. 11/12) + expert-guided episodes
(Algorithm 2). Optimiser: mini-batch Adam (paper: "Optimize the network by
mini-batch SGD with Adam optimizer").
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.expert import ExpertPolicy
from repro.core.mdp import Pipeline, QoSWeights
from repro.core.policy import (action_to_config, config_to_action, head_sizes,
                               init_policy, log_prob_entropy, sample_action)
from repro.train import adamw_init, adamw_update, clip_by_global_norm


@dataclass(frozen=True)
class PPOConfig:
    lr: float = 3e-4
    clip_eps: float = 0.2        # ε in Eq. (12)
    c1: float = 0.5              # value-loss coefficient (Eq. 11)
    c2: float = 0.01             # entropy-bonus coefficient (Eq. 11)
    gamma: float = 0.99
    gae_lambda: float = 0.95
    epochs: int = 4
    minibatch: int = 64
    expert_freq: int = 4         # every f-th episode uses expert actions (Alg. 2)
    reward_scale: float = 0.05   # keeps value targets O(1) for stable VF learning
    # Alg. 2 keeps a replay memory D of expert transitions; we distil it into
    # the policy with a behaviour-cloning auxiliary loss each update.
    bc_coef: float = 0.3
    expert_buffer: int = 8192    # max expert (s, a) pairs retained in D


def compute_gae(rewards, values, last_value, *, gamma: float, lam: float):
    """Generalised advantage estimation over one episode."""
    T = len(rewards)
    adv = np.zeros(T, dtype=np.float32)
    gae = 0.0
    for t in reversed(range(T)):
        v_next = last_value if t == T - 1 else values[t + 1]
        delta = rewards[t] + gamma * v_next - values[t]
        gae = delta + gamma * lam * gae
        adv[t] = gae
    returns = adv + values
    return adv, returns


@partial(jax.jit, static_argnames=("clip_eps", "c1", "c2", "lr"))
def ppo_minibatch_update(params, opt, states, actions, old_logp, adv, returns,
                         bc_states, bc_actions, bc_coef,
                         *, clip_eps: float, c1: float, c2: float, lr: float):
    def loss_fn(p):
        logp, ent, value = log_prob_entropy(p, states, actions)
        ratio = jnp.exp(logp - old_logp)
        clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
        l_clip = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
        l_vf = jnp.mean((value - returns) ** 2)
        l_ent = jnp.mean(ent)
        # behaviour cloning on the expert replay memory D (Alg. 2)
        bc_logp, _, _ = log_prob_entropy(p, bc_states, bc_actions)
        l_bc = -jnp.mean(bc_logp)
        loss = l_clip + c1 * l_vf - c2 * l_ent + bc_coef * l_bc
        return loss, (l_clip, l_vf, l_ent)

    (loss, (l_clip, l_vf, l_ent)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params)
    grads, _ = clip_by_global_norm(grads, 0.5)
    params, opt = adamw_update(params, grads, opt, lr=lr, weight_decay=0.0)
    return params, opt, loss, l_clip, l_vf, l_ent


class OPDTrainer:
    """Algorithm 2: expert-guided PPO training of the OPD policy."""

    def __init__(self, pipe: Pipeline, make_env, *, ppo: PPOConfig | None = None,
                 weights: QoSWeights | None = None, seed: int = 0):
        self.pipe = pipe
        self.make_env = make_env
        self.ppo = ppo or PPOConfig()
        self.expert = ExpertPolicy(pipe, weights)
        self.sizes = head_sizes(pipe)
        env = make_env(0)
        self.params = init_policy(jax.random.PRNGKey(seed), env.state_dim,
                                  self.sizes)
        self.opt = adamw_init(self.params)
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed + 1)
        self.history = {"reward": [], "loss": [], "value_loss": [],
                        "policy_loss": [], "entropy": [], "expert": []}
        # replay memory D of expert transitions (Algorithm 2)
        self.expert_states = np.zeros((0, env.state_dim), np.float32)
        self.expert_actions = np.zeros((0, len(self.sizes)), np.int32)

    def _rollout(self, env, use_expert: bool):
        states, actions, logps, rewards, values = [], [], [], [], []
        s = env.reset()
        done = False
        while not done:
            s_j = jnp.asarray(s)
            self.key, sub = jax.random.split(self.key)
            if use_expert:
                cfg = self.expert.decide(env.observe())
                a = config_to_action(self.pipe, cfg)
                logp, _, v = log_prob_entropy(
                    self.params, s_j[None], jnp.asarray(a)[None])
                logp, v = float(logp[0]), float(v[0])
            else:
                a_j, logp_j, v_j = sample_action(self.params, s_j, sub)
                a = np.asarray(a_j)
                cfg = action_to_config(self.pipe, a)
                logp, v = float(logp_j), float(v_j)
            s_next, r, done, info = env.step(cfg)
            states.append(s)
            actions.append(a)
            logps.append(logp)
            rewards.append(r)
            values.append(v)
            s = s_next
        _, _, last_v = log_prob_entropy(
            self.params, jnp.asarray(s)[None],
            jnp.asarray(actions[-1])[None])
        return (np.asarray(states, np.float32), np.asarray(actions, np.int32),
                np.asarray(logps, np.float32), np.asarray(rewards, np.float32),
                np.asarray(values, np.float32), float(last_v[0]))

    def train_episode(self, episode_idx: int, *, env_seed: int | None = None):
        cfg = self.ppo
        use_expert = cfg.expert_freq > 0 and episode_idx % cfg.expert_freq == 0
        env = self.make_env(env_seed if env_seed is not None else episode_idx)
        states, actions, logps, rewards, values, last_v = self._rollout(
            env, use_expert)
        adv, returns = compute_gae(rewards * cfg.reward_scale, values, last_v,
                                   gamma=cfg.gamma, lam=cfg.gae_lambda)
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)

        if use_expert:          # store in replay memory D (Alg. 2)
            self.expert_states = np.concatenate(
                [self.expert_states, states])[-cfg.expert_buffer:]
            self.expert_actions = np.concatenate(
                [self.expert_actions, actions])[-cfg.expert_buffer:]

        T = len(states)
        losses, pls, vls, ents = [], [], [], []
        for _ in range(cfg.epochs):
            idx = self.rng.permutation(T)
            for s0 in range(0, T, cfg.minibatch):
                sel = idx[s0:s0 + cfg.minibatch]
                # sample a fixed-size BC batch from D (dummy + coef 0 until
                # the first expert episode fills it)
                if len(self.expert_states):
                    bsel = self.rng.integers(0, len(self.expert_states),
                                             size=cfg.minibatch)
                    bc_s = self.expert_states[bsel]
                    bc_a = self.expert_actions[bsel]
                    bc_c = cfg.bc_coef
                else:
                    bc_s = states[np.zeros(cfg.minibatch, np.int64)]
                    bc_a = actions[np.zeros(cfg.minibatch, np.int64)]
                    bc_c = 0.0
                self.params, self.opt, loss, l_clip, l_vf, l_ent = \
                    ppo_minibatch_update(
                        self.params, self.opt,
                        jnp.asarray(states[sel]), jnp.asarray(actions[sel]),
                        jnp.asarray(logps[sel]), jnp.asarray(adv[sel]),
                        jnp.asarray(returns[sel]),
                        jnp.asarray(bc_s), jnp.asarray(bc_a),
                        jnp.float32(bc_c),
                        clip_eps=cfg.clip_eps, c1=cfg.c1, c2=cfg.c2, lr=cfg.lr)
                losses.append(float(loss))
                pls.append(float(l_clip))
                vls.append(float(l_vf))
                ents.append(float(l_ent))

        self.history["reward"].append(float(rewards.mean()))
        self.history["loss"].append(float(np.mean(losses)))
        self.history["policy_loss"].append(float(np.mean(pls)))
        self.history["value_loss"].append(float(np.mean(vls)))
        self.history["entropy"].append(float(np.mean(ents)))
        self.history["expert"].append(bool(use_expert))
        return self.history

    def train(self, n_episodes: int, *, log=None):
        for e in range(1, n_episodes + 1):
            self.train_episode(e)
            if log:
                log(f"episode {e}: reward={self.history['reward'][-1]:.3f} "
                    f"loss={self.history['loss'][-1]:.4f} "
                    f"vloss={self.history['value_loss'][-1]:.4f}"
                    + (" [expert]" if self.history["expert"][-1] else ""))
        return self.history
