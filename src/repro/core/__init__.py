"""OPD — the paper's contribution: MDP model, LSTM workload predictor,
residual feature extraction, PPO policy with expert guidance, baselines."""
from repro.core.mdp import (ModelVariant, Task, Pipeline, Config, QoSWeights,
                            pipeline_metrics, qos, objective, reward, feasible,
                            resource_usage)
from repro.core.predictor import (init_predictor, predict_batch, train_predictor,
                                  smape, as_predictor_fn, HISTORY, HORIZON)
from repro.core.features import init_features, extract, FEATURE_DIM
from repro.core.policy import (init_policy, apply_policy, sample_action,
                               log_prob_entropy, head_sizes, action_to_config,
                               config_to_action)
from repro.core.ppo import PPOConfig, OPDTrainer, compute_gae
from repro.core.vecenv import (PipelineTables, EnvState, tables_from_pipeline,
                               init_state, decode_action, observe, step,
                               rollout, vec_rollout, gae_scan, vec_gae)
from repro.core.expert import CapacityPolicy, ExpertPolicy, capacity_config
from repro.core.baselines import RandomPolicy, GreedyPolicy, IPAPolicy
from repro.core.opd import OPDPolicy, run_episode, run_episodes_vectorized
from repro.core.controller import Observation, ControllerBase, decide
from repro.core.forecast import (init_forecaster, forecast_batch,
                                 train_forecaster, smape_horizons,
                                 pinball_horizons, as_forecast_fn,
                                 make_forecast_dataset, telemetry_trace,
                                 HORIZONS)
from repro.core.proactive import ProactiveController
