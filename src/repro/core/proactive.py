"""Proactive pre-warm control: amortize cold starts before a forecast burst.

The reactive loop (OPD or any baseline) only reacts *after* the load moves:
a burst at t means the controller upsizes at the next adaptation interval
and then pays ``COLD_START_SECONDS`` of stage unavailability exactly while
the queue is deepest — the cold start dominates p95/p99 on bursty traces
(``runtime_throughput.json``).

``ProactiveController`` wraps any inner Controller and uses the env's
multi-horizon forecasts (``Observation.forecasts``, from
``core/forecast.py``) to split the reaction in two:

1. *now* — keep serving the inner controller's configuration for the
   current predicted load (no behavior change on the serving path);
2. *ahead* — re-run the inner controller against the forecast burst load
   and, where the burst configuration uses a different variant, publish a
   ``prewarm_plan``. The ``decide()`` driver forwards the plan to
   ``ServingRuntime.prewarm``, which pays the cold start on a standby slot
   while the live variant keeps serving; when the burst arrives and the
   inner controller actually switches, ``apply_config`` finds the variant
   warm and the switch is (close to) free.

A burst is "worth pre-warming" when the max forecast across horizons
exceeds ``margin ×`` the next-interval prediction — under that threshold
the standby slot would churn on noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.controller import ControllerBase, Observation
from repro.core.mdp import Config

# Eq. (5) column holding the predicted load m (u, p, m, l, t, ...)
_M_COL = 2


class ProactiveController(ControllerBase):
    """Wrap ``inner`` with forecast-driven variant pre-warming.

    After each ``decide`` the freshly computed standby plan is available as
    ``prewarm_plan`` — ``[(stage, variant), ...]`` — consumed by the
    ``core.controller.decide`` driver. With no forecasts on the observation
    the wrapper is transparent (plan stays empty)."""

    def __init__(self, inner, *, margin: float = 1.15):
        self.inner = inner
        self.margin = float(margin)
        self.prewarm_plan: list[tuple[int, int]] = []
        self.planned = 0            # standby warm-ups published (telemetry)

    def warmup(self, obs: Observation) -> None:
        if hasattr(self.inner, "warmup"):
            self.inner.warmup(obs)

    def _burst_obs(self, obs: Observation, burst: float) -> Observation:
        """The same snapshot re-projected to the forecast burst: the
        predicted-load feature (column m of every Eq. 5 task row) and
        ``predicted_load`` are replaced by the burst load, so the inner
        controller answers "how would you configure *for the burst*?"."""
        n_tasks = len(obs.config.z)
        state = np.array(obs.state, dtype=np.float32).reshape(n_tasks, -1)
        state[:, _M_COL] = burst / 100.0
        return dataclasses.replace(obs, state=state.reshape(-1),
                                   predicted_load=float(burst))

    def decide(self, obs: Observation) -> Config:
        cfg = self.inner.decide(obs)
        self.prewarm_plan = []
        if obs.forecasts:
            burst = max(obs.forecasts)
            if burst > self.margin * max(obs.predicted_load, 1e-9):
                ahead = self.inner.decide(self._burst_obs(obs, burst))
                self.prewarm_plan = [
                    (i, int(ahead.z[i])) for i in range(len(cfg.z))
                    if ahead.z[i] != cfg.z[i]]
                self.planned += len(self.prewarm_plan)
        return cfg
