"""Public controller-facing interface.

A controller is anything implementing ``decide(obs: Observation) -> Config``.
``Observation`` is the *public* snapshot an environment hands the controller
each adaptation interval — the Eq. (5) state vector plus the live config and
the monitor's current/predicted load — so policies no longer reach into
``env._observe()`` / ``env._predicted_load()`` private APIs.

``ControllerBase`` keeps the legacy ``policy(env)`` call style working as a
back-compat shim (it builds the Observation via ``env.observe()``), and the
module-level ``decide(controller, env)`` helper lets drivers accept both new
protocol objects and bare ``(env) -> Config`` callables.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.mdp import Config


@dataclass(frozen=True, eq=False)
class Observation:
    """What a controller may observe at decision time (public API)."""
    state: np.ndarray        # Eq. (5) feature vector, [n_tasks * 9]
    config: Config           # configuration currently live
    current_load: float      # newest monitored arrival rate (req/s)
    predicted_load: float    # predictor's load estimate for the next interval


@runtime_checkable
class Controller(Protocol):
    """Anything deciding a Config from a public Observation."""

    def decide(self, obs: Observation) -> Config: ...


class ControllerBase:
    """Base for controllers: implement ``decide``; ``__call__(env)`` is the
    back-compat shim for legacy ``policy(env)`` call sites."""

    def decide(self, obs: Observation) -> Config:
        raise NotImplementedError

    def __call__(self, env) -> Config:
        return self.decide(env.observe())


def decide(controller, env) -> Config:
    """Invoke ``controller`` on ``env``: prefer the Observation protocol,
    fall back to the legacy ``(env) -> Config`` callable style."""
    if hasattr(controller, "decide"):
        return controller.decide(env.observe())
    return controller(env)
