"""Public controller-facing interface.

A controller is anything implementing ``decide(obs: Observation) -> Config``.
``Observation`` is the *public* snapshot an environment hands the controller
each adaptation interval — the Eq. (5) state vector plus the live config and
the monitor's current/predicted load — so policies no longer reach into
``env._observe()`` / ``env._predicted_load()`` private APIs.

``ControllerBase`` keeps the legacy ``policy(env)`` call style working as a
back-compat shim (it builds the Observation via ``env.observe()``), and the
module-level ``decide(controller, env)`` helper lets drivers accept both new
protocol objects and bare ``(env) -> Config`` callables.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.mdp import Config


@dataclass(frozen=True, eq=False)
class Observation:
    """What a controller may observe at decision time (public API)."""
    state: np.ndarray        # Eq. (5) feature vector, [n_tasks * 9]
    config: Config           # configuration currently live
    current_load: float      # newest monitored arrival rate (req/s)
    predicted_load: float    # predictor's load estimate for the next interval
    # multi-horizon forecasts (core/forecast.py), when the env carries a
    # forecaster: forecasts[k] = predicted max load over the next
    # horizons[k] seconds. None otherwise — absent, not zero, so policies
    # can distinguish "no forecaster" from "forecast of 0".
    forecasts: tuple[float, ...] | None = None
    horizons: tuple[int, ...] | None = None


@runtime_checkable
class Controller(Protocol):
    """Anything deciding a Config from a public Observation."""

    def decide(self, obs: Observation) -> Config: ...


class ControllerBase:
    """Base for controllers: implement ``decide``; ``__call__(env)`` is the
    back-compat shim for legacy ``policy(env)`` call sites."""

    def decide(self, obs: Observation) -> Config:
        raise NotImplementedError

    def __call__(self, env) -> Config:
        return self.decide(env.observe())


def decide(controller, env) -> Config:
    """Invoke ``controller`` on ``env``: prefer the Observation protocol,
    fall back to the legacy ``(env) -> Config`` callable style.

    Proactive controllers may additionally publish a ``prewarm_plan`` —
    ``[(stage, variant), ...]`` standby warm-ups to start this interval —
    which is forwarded to the env's live runtime when it has one
    (``RuntimeEnv``); the analytic env has no warm/cold machinery, so the
    plan is a no-op there."""
    if hasattr(controller, "decide"):
        cfg = controller.decide(env.observe())
        plan = getattr(controller, "prewarm_plan", None)
        runtime = getattr(env, "runtime", None)
        if plan and runtime is not None and hasattr(runtime, "prewarm"):
            for stage, variant in plan:
                runtime.prewarm(int(stage), int(variant))
        return cfg
    return controller(env)
