"""Expert optimiser for guided PPO training (Algorithm 2: "Initialize expert
optimizer as expert model ... a_t <- action from expert_model given s_t").

The expert does multi-start coordinate descent on the true reward (Eq. 7)
under the simulator's known physics — per task, scan all (z, f, b) holding
the other tasks fixed, sweeping until no improvement. Starts: the live
config (warm), the min-cost config, and a capacity-first config — single
-start descent gets trapped under high load where several stages must scale
together. Strong, cheap, and distinct from the IPA baseline's accuracy-first
product enumeration.
"""
from __future__ import annotations

import numpy as np

from repro.core.controller import ControllerBase, Observation
from repro.core.mdp import Config, Pipeline, QoSWeights, feasible, reward


class ExpertPolicy(ControllerBase):
    def __init__(self, pipe: Pipeline, weights: QoSWeights | None = None,
                 sweeps: int = 3):
        self.pipe = pipe
        self.w = weights or QoSWeights()
        self.sweeps = sweeps

    # ------------------------------------------------------------ starts --

    def _min_cost_start(self) -> Config:
        pipe = self.pipe
        z = tuple(int(np.argmin([v.cost for v in t.variants]))
                  for t in pipe.tasks)
        return Config(z=z, f=tuple(1 for _ in pipe.tasks),
                      b=tuple(1 for _ in pipe.tasks))

    def _capacity_start(self, demand: float) -> Config:
        """Cheapest (z, f, b) per stage whose throughput covers demand,
        placed stage by stage through the shared placement scheduler (on a
        scalar pool this is exactly the legacy remaining-budget loop)."""
        pipe = self.pipe
        bc = pipe.batch_choices()
        z, f, b = [], [], []
        cursor = pipe.topo.cursor()
        for task in pipe.tasks:
            best = None
            for zi, var in enumerate(task.variants):
                for fi in range(1, pipe.f_max + 1):
                    if not cursor.can_place(var.resource, fi):
                        break
                    for bi in bc:
                        if var.throughput(bi, fi) >= demand:
                            cand = (fi * var.cost, var.latency(bi), zi, fi, bi)
                            if best is None or cand < best:
                                best = cand
                            break
            if best is None:
                best = (0, 0, 0, 1, 1)
            _, _, zi, fi, bi = best
            cursor.place(task.variants[zi].resource, fi)
            z.append(zi), f.append(fi), b.append(bi)
        return Config(z=tuple(z), f=tuple(f), b=tuple(b))

    # ----------------------------------------------------------- descent --

    def _descend(self, cfg: Config, demand: float) -> tuple[Config, float]:
        pipe = self.pipe
        bc = pipe.batch_choices()
        best_r = reward(pipe, cfg, demand, self.w)
        for _ in range(self.sweeps):
            improved = False
            for n, task in enumerate(pipe.tasks):
                for zi in range(len(task.variants)):
                    for fi in range(1, pipe.f_max + 1):
                        for bi in bc:
                            cand = Config(
                                z=cfg.z[:n] + (zi,) + cfg.z[n + 1:],
                                f=cfg.f[:n] + (fi,) + cfg.f[n + 1:],
                                b=cfg.b[:n] + (bi,) + cfg.b[n + 1:])
                            if not feasible(pipe, cand):
                                continue
                            r = reward(pipe, cand, demand, self.w)
                            if r > best_r:
                                cfg, best_r = cand, r
                                improved = True
            if not improved:
                break
        return cfg, best_r

    def decide(self, obs: Observation) -> Config:
        pipe = self.pipe
        demand = obs.predicted_load
        warm = (obs.config if feasible(pipe, obs.config)
                else self._min_cost_start())
        best_cfg, best_r = None, -np.inf
        for start in (warm, self._min_cost_start(),
                      self._capacity_start(demand)):
            cfg, r = self._descend(start, demand)
            if r > best_r:
                best_cfg, best_r = cfg, r
        return best_cfg
