"""Expert optimiser for guided PPO training (Algorithm 2: "Initialize expert
optimizer as expert model ... a_t <- action from expert_model given s_t").

The expert does multi-start coordinate descent on the true reward (Eq. 7)
under the simulator's known physics — per task, scan all (z, f, b) holding
the other tasks fixed, sweeping until no improvement. Starts: the live
config (warm), the min-cost config, and a capacity-first config — single
-start descent gets trapped under high load where several stages must scale
together. Strong, cheap, and distinct from the IPA baseline's accuracy-first
product enumeration.
"""
from __future__ import annotations

import numpy as np

from repro.core.controller import ControllerBase, Observation
from repro.core.mdp import Config, Pipeline, QoSWeights, feasible, reward


def capacity_config(pipe: Pipeline, demand: float,
                    prefer: str = "latency") -> Config:
    """Cheapest (z, f, b) per stage whose throughput covers demand, placed
    stage by stage through the shared placement scheduler (on a scalar pool
    this is exactly the legacy remaining-budget loop).

    ``prefer`` breaks ties among equal-cost demand-covering variants:
    ``"latency"`` (default — the expert's capacity start, keeps its
    historical behavior) picks the fastest, ``"accuracy"`` picks the most
    accurate. The accuracy preference is what makes the variant *switch*
    with demand on this pipeline's near-uniform per-replica costs: low load
    is served by accurate slow variants and bursts degrade to fast ones —
    which is what gives the proactive pre-warm slot something to warm."""
    bc = pipe.batch_choices()
    z, f, b = [], [], []
    cursor = pipe.topo.cursor()
    for task in pipe.tasks:
        best = None
        for zi, var in enumerate(task.variants):
            tie = -var.accuracy if prefer == "accuracy" else None
            for fi in range(1, pipe.f_max + 1):
                if not cursor.can_place(var.resource, fi):
                    break
                for bi in bc:
                    if var.throughput(bi, fi) >= demand:
                        key = var.latency(bi) if tie is None else tie
                        cand = (fi * var.cost, key, zi, fi, bi)
                        if best is None or cand < best:
                            best = cand
                        break
        if best is None:
            best = (0, 0, 0, 1, 1)
        _, _, zi, fi, bi = best
        cursor.place(task.variants[zi].resource, fi)
        z.append(zi), f.append(fi), b.append(bi)
    return Config(z=tuple(z), f=tuple(f), b=tuple(b))


class CapacityPolicy(ControllerBase):
    """Demand-matched min-cost controller with adaptive degradation: serve
    the predicted load with the cheapest demand-covering configuration,
    preferring the most accurate variant at equal cost. The cost-first
    counterpart of the reward-descending expert — and the inner controller
    of the headline proactive arm in fig45: its variant choice tracks load
    (so forecasts pre-warm real switches) at a config cost below the flat
    reactive baselines."""

    def __init__(self, pipe: Pipeline):
        self.pipe = pipe

    def decide(self, obs: Observation) -> Config:
        return capacity_config(self.pipe, obs.predicted_load,
                               prefer="accuracy")


class ExpertPolicy(ControllerBase):
    def __init__(self, pipe: Pipeline, weights: QoSWeights | None = None,
                 sweeps: int = 3):
        self.pipe = pipe
        self.w = weights or QoSWeights()
        self.sweeps = sweeps

    # ------------------------------------------------------------ starts --

    def _min_cost_start(self) -> Config:
        pipe = self.pipe
        z = tuple(int(np.argmin([v.cost for v in t.variants]))
                  for t in pipe.tasks)
        return Config(z=z, f=tuple(1 for _ in pipe.tasks),
                      b=tuple(1 for _ in pipe.tasks))

    def _capacity_start(self, demand: float) -> Config:
        return capacity_config(self.pipe, demand)

    # ----------------------------------------------------------- descent --

    def _descend(self, cfg: Config, demand: float) -> tuple[Config, float]:
        pipe = self.pipe
        bc = pipe.batch_choices()
        best_r = reward(pipe, cfg, demand, self.w)
        for _ in range(self.sweeps):
            improved = False
            for n, task in enumerate(pipe.tasks):
                for zi in range(len(task.variants)):
                    for fi in range(1, pipe.f_max + 1):
                        for bi in bc:
                            cand = Config(
                                z=cfg.z[:n] + (zi,) + cfg.z[n + 1:],
                                f=cfg.f[:n] + (fi,) + cfg.f[n + 1:],
                                b=cfg.b[:n] + (bi,) + cfg.b[n + 1:])
                            if not feasible(pipe, cand):
                                continue
                            r = reward(pipe, cand, demand, self.w)
                            if r > best_r:
                                cfg, best_r = cand, r
                                improved = True
            if not improved:
                break
        return cfg, best_r

    def decide(self, obs: Observation) -> Config:
        pipe = self.pipe
        demand = obs.predicted_load
        warm = (obs.config if feasible(pipe, obs.config)
                else self._min_cost_start())
        best_cfg, best_r = None, -np.inf
        for start in (warm, self._min_cost_start(),
                      self._capacity_start(demand)):
            cfg, r = self._descend(start, demand)
            if r > best_r:
                best_cfg, best_r = cfg, r
        return best_cfg
