"""LSTM workload predictor (paper §IV-A, Fig. 3).

"predict the maximum workload for the next 20 seconds based on a time series
of loads per second collected over the past 2 minutes. The model architecture
includes a 25-unit LSTM layer followed by a one-unit dense output layer."
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.train import adamw_update, adamw_init

HISTORY = 120
HORIZON = 20
HIDDEN = 25


def init_predictor(key):
    k1, k2 = jax.random.split(key)
    return {
        "lstm": nn.init_lstm(k1, 1, HIDDEN),
        "out": nn.init_linear(k2, HIDDEN, 1, bias=True),
    }


@jax.jit
def predict_batch(params, hist):
    """hist [B, HISTORY] (normalised) -> predicted max load [B]."""
    _, (hT, _) = nn.lstm_scan(params["lstm"], hist[..., None])
    return nn.linear(params["out"], hT)[..., 0]


def make_dataset(traces: list[np.ndarray], *, scale: float):
    """Sliding windows -> (X [M, HISTORY], y [M]) normalised by ``scale``."""
    xs, ys = [], []
    for tr in traces:
        for s in range(0, len(tr) - HISTORY - HORIZON):
            xs.append(tr[s:s + HISTORY])
            ys.append(tr[s + HISTORY:s + HISTORY + HORIZON].max())
    X = np.asarray(xs, dtype=np.float32) / scale
    y = np.asarray(ys, dtype=np.float32) / scale
    return X, y


@jax.jit
def _train_step(params, opt, xb, yb, lr):
    def loss_fn(p):
        pred = predict_batch(p, xb)
        return jnp.mean((pred - yb) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt = adamw_update(params, grads, opt, lr=lr, weight_decay=0.0)
    return params, opt, loss


def train_predictor(traces: list[np.ndarray], *, scale: float, epochs: int = 5,
                    batch: int = 256, seed: int = 0, lr: float = 5e-3, log=None):
    X, y = make_dataset(traces, scale=scale)
    if len(X) == 0:
        raise ValueError(
            f"empty predictor dataset: need traces longer than "
            f"HISTORY + HORIZON = {HISTORY + HORIZON} s "
            f"(got {[len(t) for t in traces]})")
    # clamp so short traces (quick mode, small regimes) still take gradient
    # steps — an oversized batch would make the step loop below empty and
    # silently return untrained params
    batch = min(int(batch), len(X))
    rng = np.random.default_rng(seed)
    params = init_predictor(jax.random.PRNGKey(seed))
    # start the output head at the target mean — removes the large constant
    # bias error the optimizer would otherwise spend epochs walking off
    params["out"]["b"] = params["out"]["b"] + float(y.mean())
    opt = adamw_init(params)
    n_steps = max(1, (len(X) - batch + 1 + batch - 1) // batch) * epochs
    step = 0
    for e in range(epochs):
        idx = rng.permutation(len(X))
        losses = []
        for s in range(0, len(X) - batch + 1, batch):
            sel = idx[s:s + batch]
            # cosine decay to 10% of peak lr
            cur_lr = lr * (0.55 + 0.45 * np.cos(np.pi * step / n_steps))
            params, opt, loss = _train_step(params, opt, jnp.asarray(X[sel]),
                                            jnp.asarray(y[sel]),
                                            jnp.float32(cur_lr))
            losses.append(float(loss))
            step += 1
        if log:
            log(f"predictor epoch {e}: mse={np.mean(losses):.5f}")
    return params


def smape(params, traces: list[np.ndarray], *, scale: float) -> float:
    """Symmetric mean absolute percentage error (paper reports ~6%)."""
    X, y = make_dataset(traces, scale=scale)
    pred = np.asarray(predict_batch(params, jnp.asarray(X)))
    return float(np.mean(2.0 * np.abs(pred - y) /
                         (np.abs(pred) + np.abs(y) + 1e-9)) * 100.0)


def as_predictor_fn(params, *, scale: float):
    """Adapter for PipelineEnv: load_history [HISTORY] -> predicted load.

    Advertises ``fn.min_history`` so callers can fall back to the
    last-observed load while the monitor window is still padded (see
    ``Monitor.valid``) — the model never trained on constant-padded input.
    """
    def fn(hist: np.ndarray) -> float:
        h = jnp.asarray(hist[-HISTORY:], dtype=jnp.float32)[None] / scale
        return float(predict_batch(params, h)[0]) * scale
    fn.min_history = HISTORY
    return fn
