"""Jitted, vmappable twin of the discrete-event serving runtime.

``serving.runtime.ServingRuntime`` steps a Python ``heapq`` one event at a
time — exact, but single-env and far slower than training wants. This module
re-expresses the same dynamics as a pure-JAX event loop so a full
closed-loop adaptation episode (policy decision every
``ADAPTATION_INTERVAL``, measured-telemetry reward per Eq. (3)/(7)) is one
``lax.scan`` over intervals, vmappable across environments — the runtime
counterpart of ``core.vecenv``'s analytic twin.

Instead of heaped timer events, the twin *derives* each stage's next
dispatch instant from its queue state (timeout-or-full continuous batching,
cold-start gate, free-replica gate) and advances an inner ``lax.while_loop``
one event at a time, always processing the earliest of

  dispatch < completion

(the priority order mirrors the Python loop's FIFO tie-breaking; with
continuous arrival times, exact ties are measure zero). Neither arrivals
nor transfer deliveries are events. The pre-generated arrival array is
sorted and immutable, so stage 0's queue is *virtual* — a head pointer into
the arrival array, which ``init_state`` lays into queue-buffer row 0 so
every stage reads through one uniform window; a dispatch counts how many
arrivals have landed within its 2B-wide head window.
Cross-node transfers get the same treatment: a forwarded completion writes
its batch into the next stage's queue immediately, stamped with its
*delivery* time (``now + hop``), and every dispatch-timer quantity — the
timeout anchor, the batch-full instant, the poppable count — is derived
from those stamps, so a separate delivery event would change nothing the
loop can observe. Downstream per-stage queues are append-only buffers
sized to the episode's arrival count; per-replica slots pin (variant,
batch, node speed) at dispatch exactly like the event loop, so mid-flight
reconfigurations never change an already-running batch.
Placement reuses ``vecenv._placement`` — the float32 scheduler twin whose
discrete decisions are bit-identical to the Python first-fit scheduler — so
replica slot speeds, primary nodes, and cross-node hop penalties match
``ServingRuntime`` exactly.

Performance shape: the env axis is threaded *explicitly* through the event
loop rather than via ``vmap`` — ``while_loop``'s batching rule wraps every
carry array in a per-iteration ``select(done, old, new)``, which copies
the multi-MB queue buffer once per event; with a scalar ``jnp.any``
condition and self-masking envs the buffer keeps a single consumer (its
enqueue scatter) and XLA mutates it in place. Three things keep the loop
body lean on CPU, where it is kernel-launch bound (~35 fused kernels per
event at a microsecond each):

- the queue buffer sees exactly one scatter per event (the forward
  enqueue); everything else is gathers and one-hot masked vector math.
  In-flight batches pin their *head index* (``fl_head``), not their
  contents — the buffer is append-only, so one gather at completion
  recovers the batch's arrival times, where pinning the times themselves
  would cost a second scatter (vmapped ``dynamic_update_slice`` lowers to
  a sequential per-env loop on CPU XLA — gathers don't);
- ``select`` runs on carried per-stage head / batch-full delivery stamps
  (``r_head`` / ``r_full``, refreshed from the buffer once per interval,
  maintained incrementally per event), so picking the next event never
  touches the big buffer; the loop body patches the one stage a dispatch
  changed and re-runs the argmin instead of recomputing ``select``;
- a completion replays the dispatch timers on the post-completion state
  and, when some stage is due at that same instant (the freed replica's
  stage, or the one its forwarded batch just filled), processes that
  dispatch in the same iteration — provably the globally-next event, and
  under load it nearly halves the iteration count.

Exact vs approximate w.r.t. the event loop:

- *exact*: event ordering, batch formation, replica claiming (fastest free,
  ties lowest slot), service times, cold-start gating, placement decisions,
  transfer delivery times (including transfers in flight across a
  reconfiguration — their stamps keep the hop they departed with),
  interval scoring formulas, arrival streams (shared
  ``ArrivalProcess.times``);
- *approximate*: times are float32, so completions landing within ~1e-4 s
  of an interval boundary may be counted one interval over — served counts
  match within a request or two and episode rewards within float tolerance
  (``tests/test_runtime_vec.py`` pins both against ``ServingRuntime``).
  Queues pop strictly FIFO in *enqueue* order; if a re-placement changes a
  hop while transfers are in flight, delivery stamps across the boundary
  can be momentarily non-monotone and a pop may wait out the older stamp
  (at most one hop, ~tens of ms).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np  # reprolint: ignore[RPL002] host-side arrival-array prep only (episode_arrivals/stack_episodes)

from repro.analysis import sanitize
from repro.core.mdp import (ADAPTATION_INTERVAL, COLD_START_FRACTION,
                            QoSWeights)
from repro.core.policy import apply_policy, sample_action
from repro.core.vecenv import (PipelineTables, _gather, _placement,
                               decode_action, observe_cfg)

INF = jnp.float32(jnp.inf)
COLD_START_SECONDS = COLD_START_FRACTION * ADAPTATION_INTERVAL
DEFAULT_MAX_WAIT = 0.25          # mirrors serving.runtime.DEFAULT_MAX_WAIT
_ARRIVAL_BUCKET = 512            # arrival arrays pad to multiples of this
# guaranteed inf-padding at the tail of every arrival array, so the event
# loop's 2B-wide head window is always a plain in-bounds dynamic_slice
# (requires 2 * b_max <= _ARRIVAL_PAD — checked in init_state)
_ARRIVAL_PAD = 64


class EpisodeArrivals(NamedTuple):
    """One episode's pre-generated arrival stream plus the host-precomputed
    per-interval statistics the reward/observation need (computed in float64
    from the exact times, so demand and measured load match the Python
    telemetry bit-for-bit)."""
    times: jax.Array         # [N_cap] f32 arrival instants, padded with inf
    arrived: jax.Array       # [T] f32  arrivals in [10k, 10k+10)
    load_obs: jax.Array      # [T] f32  measured load at decision k (req/s)


class RuntimeState(NamedTuple):
    """The twin's full event-loop state (one environment)."""
    now: jax.Array           # f32 virtual clock
    arr_idx: jax.Array       # i32 arrivals landed by the last boundary
    q_buf: jax.Array         # [S, Q, 2] f32 append-only queue:
                             #   [..., 0] original arrival time
                             #   [..., 1] delivery time at this stage
                             #     (completion time + hop; a stamp in the
                             #      future means the batch is still in
                             #      cross-node transfer)
                             #   (row 0 holds the episode's arrival array
                             #    in both columns — stage 0's "queue" —
                             #    so every read is uniform across stages)
    q_head: jax.Array        # [S] i32 (monotone, no wraparound; head 0
                             #   indexes the episode's arrival array)
    q_len: jax.Array         # [S] i32 enqueued requests (head..head+len)
    r_head: jax.Array        # [S] f32 head delivery stamp (valid while
                             #   q_len > 0; stage 0: times[head], inf past
                             #   the last arrival) — carried so ``select``
                             #   never gathers from the big queue buffer
    r_full: jax.Array        # [S] f32 delivery stamp of the b-th queued
                             #   request (valid while q_len >= b)
    fl_finish: jax.Array     # [S, R] f32 in-flight finish time (inf = free)
    fl_size: jax.Array       # [S, R] i32 pinned batch size
    fl_head: jax.Array       # [S, R] i32 queue index of the batch's first
                             #   request at dispatch — the buffer is
                             #   append-only, so the batch's arrival times
                             #   are still there at completion (pinning an
                             #   index instead of copying the times keeps
                             #   the dispatch path free of batched scatters)
    blocked: jax.Array       # [S] f32 cold-start gate
    z: jax.Array             # [S] i32 live variant
    f: jax.Array             # [S] i32 live replicas
    b: jax.Array             # [S] i32 live batch size
    slot_speed: jax.Array    # [S, R] f32 node speed of each replica slot
    hop_next: jax.Array      # [S] f32 transfer delay stage s -> s+1 (last 0)
    completed: jax.Array     # f32 completions this interval
    lat_sum: jax.Array       # f32 Σ end-to-end latency this interval


# ---------------------------------------------------------------- episode --

def episode_arrivals(process, horizon: int, *,
                     n_cap: int | None = None) -> EpisodeArrivals:
    """Host-side precomputation of one episode's arrivals: the shared
    ``process.times(horizon)`` array (identical to what ``ServingRuntime.
    load`` consumes) padded to a static bucketed capacity, plus exact
    float64 per-interval arrival counts and the per-second measured load the
    predictor-free observation reads (``RuntimeEnv`` prefills its monitor
    with the t=0 expected rate; afterwards the newest monitor slot is the
    arrival count of the second before each decision)."""
    t = np.asarray(process.times(horizon), np.float64)
    n_steps = max(1, int(horizon) // ADAPTATION_INTERVAL)
    edges = np.arange(n_steps + 1, dtype=np.float64) * ADAPTATION_INTERVAL
    arrived = np.histogram(t, bins=edges)[0].astype(np.float64)
    load_obs = np.empty(n_steps, np.float64)
    load_obs[0] = float(process.rates(1)[0])
    for k in range(1, n_steps):
        s = k * ADAPTATION_INTERVAL - 1
        load_obs[k] = np.count_nonzero((t >= s) & (t < s + 1))
    if n_cap is None:
        n_cap = (int(np.ceil((len(t) + _ARRIVAL_PAD) / _ARRIVAL_BUCKET))
                 * _ARRIVAL_BUCKET)
    if len(t) > n_cap - _ARRIVAL_PAD:
        raise ValueError(f"n_cap={n_cap} < {len(t)} arrivals + pad")
    padded = np.full(n_cap, np.inf, np.float32)
    padded[:len(t)] = t.astype(np.float32)
    return EpisodeArrivals(times=jnp.asarray(padded),
                           arrived=jnp.asarray(arrived, jnp.float32),
                           load_obs=jnp.asarray(load_obs, jnp.float32))


def stack_episodes(eps: list[EpisodeArrivals]) -> EpisodeArrivals:
    """Batch per-env episodes along a leading axis (re-padding arrival
    arrays to the widest bucket) for ``vec_rollout``."""
    n_cap = max(e.times.shape[0] for e in eps)
    times = np.full((len(eps), n_cap), np.inf, np.float32)
    for i, e in enumerate(eps):
        times[i, :e.times.shape[0]] = np.asarray(e.times)
    return EpisodeArrivals(
        times=jnp.asarray(times),
        arrived=jnp.stack([e.arrived for e in eps]),
        load_obs=jnp.stack([e.load_obs for e in eps]))


# ------------------------------------------------------------------ state --

def init_state(tables: PipelineTables, ep: EpisodeArrivals) -> RuntimeState:
    """Episode start: default configuration (z=0, f=1, b=1) already placed,
    empty queues, idle replicas — mirroring ``RuntimeEnv.reset``."""
    S = tables.n_tasks
    R = tables.replica_slots.shape[0]
    B = tables.batch_slots.shape[0]
    if 2 * B > _ARRIVAL_PAD:
        raise ValueError(
            f"2*b_max={2 * B} exceeds arrival padding {_ARRIVAL_PAD}")
    # every request enqueues at each stage exactly once, so the append-only
    # buffer needs arrival capacity + one batch of write headroom
    Q = ep.times.shape[0] + B
    z0 = jnp.zeros(S, jnp.int32)
    f0 = jnp.ones(S, jnp.int32)
    slot_speed, hop_next = _install_placement(tables, z0, f0)
    # stage 0's queue row holds the episode's (inf-padded) arrival array in
    # both columns: a request's stage-0 "delivery" is its arrival. The last
    # B lanes stay inf — that's where masked-off enqueue writes land, and
    # no read reaches past times' own _ARRIVAL_PAD inf tail before it
    row0 = jnp.full(Q, jnp.inf, jnp.float32).at[:ep.times.shape[0]].set(
        ep.times)
    q_buf = jnp.zeros((S, Q, 2), jnp.float32)
    q_buf = q_buf.at[0, :, 0].set(row0).at[0, :, 1].set(row0)
    return RuntimeState(
        now=jnp.float32(0.0), arr_idx=jnp.int32(0),
        q_buf=q_buf,
        q_head=jnp.zeros(S, jnp.int32), q_len=jnp.zeros(S, jnp.int32),
        r_head=jnp.full(S, jnp.inf, jnp.float32),
        r_full=jnp.full(S, jnp.inf, jnp.float32),
        fl_finish=jnp.full((S, R), jnp.inf, jnp.float32),
        fl_size=jnp.zeros((S, R), jnp.int32),
        fl_head=jnp.zeros((S, R), jnp.int32),
        blocked=jnp.zeros(S, jnp.float32),
        z=z0, f=f0, b=jnp.ones(S, jnp.int32),
        slot_speed=slot_speed, hop_next=hop_next,
        completed=jnp.float32(0.0), lat_sum=jnp.float32(0.0))


def _install_placement(tables: PipelineTables, z: jax.Array, f: jax.Array):
    """(slot_speed [S, R], hop_next [S]) of configuration (z, f) — the twin
    of ``ServingRuntime._install_placement``."""
    S = tables.n_tasks
    R = tables.replica_slots.shape[0]
    if tables.n_nodes == 0:            # scalar pool: unit speed, no hops
        return jnp.ones((S, R), jnp.float32), jnp.zeros(S, jnp.float32)
    pl = _placement(tables, z, f)
    hop = jnp.where(pl.primary[:-1] != pl.primary[1:], tables.hop_latency,
                    0.0).astype(jnp.float32)
    return pl.slot_speed, jnp.concatenate([hop, jnp.zeros(1, jnp.float32)])


# -------------------------------------------------------------- event loop --

def _advance(tables: PipelineTables, state: RuntimeState,
             times: jax.Array, t_end: jax.Array,
             max_wait: jax.Array) -> RuntimeState:
    """Process every event with time <= t_end (one ``lax.while_loop``
    iteration per event), leaving every env's clock at t_end — the twin of
    ``ServingRuntime.run_until``.

    ``state`` carries an explicit leading env axis and the loop condition
    reduces over it. Putting the whole loop under ``vmap`` instead would
    invoke ``while_loop``'s batching rule, which wraps every carry array in
    a per-iteration ``select(done, old, new)`` — a full copy of the
    multi-MB queue buffer per event. With a scalar ``jnp.any`` condition
    the queue buffer keeps a single consumer (its enqueue scatter), XLA
    updates it in place, and envs that have drained their interval mask
    their own effects (~5x wall clock on CPU at 32 envs).
    """
    S = tables.n_tasks
    R = tables.replica_slots.shape[0]
    B = tables.batch_slots.shape[0]
    Q = state.q_buf.shape[2]
    iota_s = jnp.arange(S, dtype=jnp.int32)
    iota_r = jnp.arange(R, dtype=jnp.int32)
    iota_b = jnp.arange(B, dtype=jnp.int32)
    iota_b2 = jnp.arange(2 * B, dtype=jnp.int32)
    # per-interval constants: the live configuration is fixed between
    # reconfigurations, so service coefficients resolve once per _advance
    a_z = jax.vmap(lambda z: _gather(tables.alpha, z))(state.z)
    b_z = jax.vmap(lambda z: _gather(tables.beta, z))(state.z)

    def row_s(arr, s):
        """arr [S, ...] at dynamic stage s via one-hot sum (vector math in
        place of a batched gather; rows are mutually exclusive so the sum
        selects — inf entries survive as 0 + inf)."""
        mask = (iota_s == s).reshape((S,) + (1,) * (arr.ndim - 1))
        return jnp.sum(jnp.where(mask, arr, 0), axis=0)

    def refresh(st: RuntimeState):
        """Recompute the carried head / batch-full delivery stamps from the
        queue buffers — once per interval (a reconfiguration can change
        ``b``, moving the batch-full position). Inside the event loop the
        stamps are maintained incrementally from the dispatch window and
        enqueue writes, so ``select`` never touches the big buffer. The
        inf tails keep head + b - 1 in bounds and return inf when stage
        0's remaining arrivals can't fill a batch."""
        r_head = st.q_buf[iota_s, jnp.minimum(st.q_head, Q - 1), 1]
        r_full = st.q_buf[iota_s,
                          jnp.minimum(st.q_head + st.b - 1, Q - 1), 1]
        return st._replace(r_head=r_head, r_full=r_full)

    def select(st: RuntimeState):
        """One env's earliest pending event: (t_next, ev, s_disp, s_cmp,
        r_cmp). ev: 0=dispatch, 1=completion. Pure small-vector math over
        the carried per-stage stamps: batch-full and timeout instants
        derive from delivery stamps, so future arrivals and in-flight
        transfers schedule dispatches without ever being events
        themselves."""
        in_flight = jnp.sum(st.fl_finish < INF, axis=1)
        has_any = jnp.where(iota_s == 0, st.r_head < INF, st.q_len > 0)
        # max with the head stamp: stamps are monotone except momentarily
        # after a hop re-placement, and strict-FIFO popping can't start a
        # batch before its head delivers
        t_full = jnp.where(jnp.where(iota_s == 0, True, st.q_len >= st.b),
                           jnp.maximum(st.r_full, st.r_head), INF)
        t_ready = jnp.minimum(t_full,
                              jnp.where(has_any, st.r_head + max_wait, INF))
        t_disp_s = jnp.maximum(st.now, jnp.maximum(st.blocked, t_ready))
        t_disp_s = jnp.where(in_flight < st.f, t_disp_s, INF)
        # one shared argmin over [S + S*R] candidates; dispatch entries
        # come first, so the first-occurrence tie-break keeps the
        # dispatch-before-completion priority
        cand = jnp.concatenate([t_disp_s, st.fl_finish.reshape(-1)])
        idx = jnp.argmin(cand).astype(jnp.int32)
        cmp_flat = jnp.maximum(idx - S, 0)
        return (jnp.min(cand), (idx >= S).astype(jnp.int32),
                jnp.minimum(idx, S - 1), cmp_flat // R, cmp_flat % R)

    def body_env(st, sel, active, cpack_e):
        """One env, one event — every effect is masked by ``active`` so a
        drained env is a no-op while its siblings catch up."""
        now, ev, s_disp, s_cmp, r_cmp = sel
        is_cmp = active & (ev == 1)

        # -- completion: free the slot; final stage -> telemetry, else the
        #    batch enters the next stage's queue immediately, stamped with
        #    its delivery time (now + hop) — the dispatch timers derive
        #    everything from the stamps, so in-flight transfers need no
        #    event of their own -------------------------------------------
        oh_cmp = (iota_s[:, None] == s_cmp) & (iota_r[None, :] == r_cmp)
        hk = jnp.sum(jnp.where(oh_cmp[None], jnp.stack([st.fl_size,
                                                        st.fl_head]), 0),
                     axis=(1, 2))
        k_cmp, hd_cmp = hk[0], hk[1]
        # the batch's arrival times still sit where they were dispatched
        # from: the buffer is append-only (slab writes land at tails past
        # them), so the pinned head index recovers them with one gather
        cmp_orig = jax.lax.dynamic_slice(
            st.q_buf, (s_cmp, hd_cmp, 0), (1, B, 2))[0, :, 0]
        last = s_cmp == S - 1
        fl_finish = jnp.where(is_cmp & oh_cmp, INF, st.fl_finish)
        done = is_cmp & last
        completed = st.completed + jnp.where(done, k_cmp, 0)
        lat_sum = st.lat_sum + jnp.where(
            done,
            k_cmp * now - jnp.sum(jnp.where(iota_b < k_cmp, cmp_orig, 0.0)),
            0.0)
        hop_cmp = row_s(st.hop_next, s_cmp)
        forward = is_cmp & ~last
        s_next = jnp.minimum(s_cmp + 1, S - 1)

        # -- the one write on the big buffer: a forwarded completion puts
        #    its batch into s+1 (stamp = delivery time, now + hop) as one
        #    contiguous dynamic_update_slice. Lanes past the batch land
        #    beyond the new tail and are overwritten before any read;
        #    masked-off events write at (0, Q - B) — the inf headroom past
        #    stage 0's arrival array, which no window read ever reaches ----
        w_s, w_k = s_next, k_cmp
        tail = row_s(st.q_head + st.q_len, w_s)
        vals = jnp.stack([cmp_orig,
                          jnp.broadcast_to(now + hop_cmp, (B,))], axis=-1)
        q_buf = jax.lax.dynamic_update_slice(
            st.q_buf, vals[None],
            (jnp.where(forward, w_s, 0), jnp.where(forward, tail, Q - B),
             0))

        # -- completion -> dispatch fusion: replay ``select``'s dispatch
        #    timers on the post-completion state — pure vector math on the
        #    carried stamps, no gathers. If any stage is due at this very
        #    instant the globally-next event is provably that dispatch
        #    (dispatches outrank completions and nothing can precede
        #    ``now``), so it is processed in the same iteration. This
        #    catches both the freed replica's stage re-dispatching and the
        #    downstream stage the forwarded batch just filled — under load
        #    most completions trigger one, halving the event count --------
        enq = forward & (iota_s == w_s)
        deliver = now + hop_cmp
        q_len_mid = st.q_len + jnp.where(enq, w_k, 0)
        r_head_mid = jnp.where(enq & (st.q_len == 0), deliver, st.r_head)
        r_full_mid = jnp.where(enq & (st.q_len < st.b)
                               & (st.q_len + w_k >= st.b), deliver,
                               st.r_full)
        in_flight = jnp.sum(fl_finish < INF, axis=1)
        has_any = jnp.where(iota_s == 0, r_head_mid < INF, q_len_mid > 0)
        t_full = jnp.where(jnp.where(iota_s == 0, True, q_len_mid >= st.b),
                           jnp.maximum(r_full_mid, r_head_mid), INF)
        t_ready = jnp.minimum(t_full,
                              jnp.where(has_any, r_head_mid + max_wait, INF))
        t_disp = jnp.maximum(now, jnp.maximum(st.blocked, t_ready))
        t_disp = jnp.where(in_flight < st.f, t_disp, INF)
        fused = is_cmp & (jnp.min(t_disp) <= now)
        s_disp = jnp.where(ev == 0, s_disp,
                           jnp.argmin(t_disp).astype(jnp.int32))
        is_disp = (active & (ev == 0)) | fused

        # -- dispatch: pop the delivered FIFO prefix (clamped to b), claim
        #    the fastest free slot. Stage 0 pops straight out of the
        #    arrival-array head window; b <= B, so the B-wide window
        #    bounds the count exactly after the min() clamp ---------------
        # one masked sum selects every per-stage constant the dispatch
        # needs (b, f, blocked, alpha, beta — fixed for the interval, so
        # the [5, S] pack is built once outside the loop), and a second
        # the two mutable cursors — five reductions become two
        oh_d = iota_s == s_disp
        seld = jnp.sum(jnp.where(oh_d[None, :], cpack_e, 0.0), axis=1)
        b_d = seld[0].astype(jnp.int32)
        f_d = seld[1].astype(jnp.int32)
        hq = jnp.sum(jnp.where(oh_d[None, :],
                               jnp.stack([st.q_head, q_len_mid]), 0), axis=1)
        head_d = hq[0]
        q_slice = jax.lax.dynamic_slice(
            q_buf, (s_disp, head_d, 0), (1, 2 * B, 2)).reshape(2 * B, 2)
        orig_src = q_slice[:, 0]
        stamp = q_slice[:, 1]
        # stage 0's depth is virtual (its lanes past the arrivals are inf,
        # so the stamp check alone bounds the pop)
        in_q = (s_disp == 0) | (iota_b2 < hq[1])
        # delivered prefix: stamps are monotone except momentarily after a
        # hop re-placement, where strict-FIFO popping waits out the head
        # first undelivered lane bounds the poppable prefix — argmin on the
        # bool mask, not a cumprod-sum: XLA CPU lowers cumprod to a slow
        # O(window²) reduce-window, and this loop is kernel-launch bound
        ready = (stamp <= now) & in_q
        n_avail = jnp.where(jnp.all(ready), 2 * B,
                            jnp.argmin(ready).astype(jnp.int32))
        rows = jnp.sum(jnp.where(oh_d[None, :, None],
                                 jnp.stack([fl_finish, st.slot_speed]), 0.0),
                       axis=1)
        fl_row, speed_row = rows[0], rows[1]
        n_pop = jnp.where(is_disp, jnp.minimum(b_d, n_avail), 0)
        free = (iota_r < f_d) & (fl_row == INF)
        score = jnp.where(free, speed_row, -INF)
        r_claim = jnp.argmax(score)
        service = ((seld[3] + seld[4] * n_pop)
                   / jnp.maximum(jnp.max(score), 1e-9))
        oh_claim = oh_d[:, None] & (iota_r[None, :] == r_claim)
        fl_finish = jnp.where(is_disp & oh_claim, now + service, fl_finish)
        fl_size = jnp.where(is_disp & oh_claim, n_pop, st.fl_size)
        # pin where the batch came from, not what it contained: the buffer
        # is append-only, so the head index recovers the arrival times at
        # completion — a masked vector write instead of a scatter
        fl_head = jnp.where(is_disp & oh_claim, head_d, st.fl_head)

        # -- head/len bookkeeping (one-hot on [S]; stage 0's len is
        #    virtual and reconstructed after the loop) ---------------------
        q_head = st.q_head + jnp.where(is_disp & oh_d, n_pop, 0)
        q_len = (q_len_mid
                 - jnp.where(is_disp & (s_disp > 0) & oh_d, n_pop, 0))

        # -- maintain the carried stamps: the dispatching stage's new head
        #    and batch-full stamps come straight out of its 2B-wide window
        #    (n_pop <= b <= B keeps both in range); a forwarded batch
        #    stamps the destination's head when its queue was empty and
        #    its batch-full slot when the append crosses b. Off-range
        #    values are garbage, guarded by select's q_len checks ----------
        pos = jnp.stack([n_pop, n_pop + b_d - 1])
        rhf = jnp.take(stamp, pos)
        oh_disp = is_disp & oh_d
        r_head = jnp.where(oh_disp, rhf[0], r_head_mid)
        r_full = jnp.where(oh_disp, rhf[1], r_full_mid)

        st = st._replace(
            now=jnp.where(active, jnp.maximum(st.now, now), st.now),
            q_buf=q_buf, q_head=q_head, q_len=q_len,
            r_head=r_head, r_full=r_full,
            fl_finish=fl_finish, fl_size=fl_size, fl_head=fl_head,
            completed=completed, lat_sum=lat_sum)

        # -- incremental next-event pick: the dispatch timers were already
        #    replayed on the mid state above, and a dispatch only changes
        #    its own stage's entry — patch that one stage scalar-wise and
        #    redo the argmin instead of recomputing ``select`` in full.
        #    (For an idle env every entry is provably >= its pending event
        #    time, so the clamp at ``now`` is a no-op and the previous
        #    pick is reproduced exactly.) ----------------------------------
        q_len_d = hq[1] - jnp.where(s_disp > 0, n_pop, 0)
        has_any_d = jnp.where(s_disp == 0, rhf[0] < INF, q_len_d > 0)
        t_full_d = jnp.where((s_disp == 0) | (q_len_d >= b_d),
                             jnp.maximum(rhf[1], rhf[0]), INF)
        t_ready_d = jnp.minimum(
            t_full_d, jnp.where(has_any_d, rhf[0] + max_wait, INF))
        in_flight_d = jnp.sum(jnp.where(oh_d, in_flight, 0)) + 1
        t_disp_d = jnp.maximum(now, jnp.maximum(seld[2], t_ready_d))
        t_disp_d = jnp.where(in_flight_d < f_d, t_disp_d, INF)
        cand = jnp.concatenate([jnp.where(oh_disp, t_disp_d, t_disp),
                                fl_finish.reshape(-1)])
        idx = jnp.argmin(cand).astype(jnp.int32)
        cmp_flat = jnp.maximum(idx - S, 0)
        return st, (jnp.min(cand), (idx >= S).astype(jnp.int32),
                    jnp.minimum(idx, S - 1), cmp_flat // R, cmp_flat % R)

    def cond(carry):
        return jnp.any(carry[1][0] <= t_end)

    # per-stage constants the dispatch path selects with one masked sum:
    # batch size, replica count, cold-start gate, service coefficients
    cpack = jnp.stack([state.b.astype(jnp.float32),
                       state.f.astype(jnp.float32),
                       state.blocked, a_z, b_z], axis=1)

    def body(carry):
        st, sel = carry
        return jax.vmap(body_env)(st, sel, sel[0] <= t_end, cpack)

    state = jax.vmap(refresh)(state)
    sel0 = jax.vmap(select)(state)
    st, _ = jax.lax.while_loop(cond, body, (state, sel0))
    # materialise stage 0's virtual queue depth at the interval boundary
    n_seen = jax.vmap(
        lambda te: jnp.searchsorted(te, t_end, side="right"))(times)
    n_seen = n_seen.astype(jnp.int32)
    q_len = st.q_len.at[:, 0].set(n_seen - st.q_head[:, 0])
    return st._replace(now=jnp.maximum(st.now, t_end),
                       arr_idx=n_seen, q_len=q_len)


# ----------------------------------------------------------------- interval --

def _analytic_latency(tables: PipelineTables, z, f, b, demand):
    """jnp twin of ``mdp.analytic_pipeline_latency`` — the smooth latency
    fallback when an interval completes nothing."""
    bf = b.astype(jnp.float32)
    fb = f.astype(jnp.float32) * bf
    lat = _gather(tables.alpha, z) + _gather(tables.beta, z) * bf
    wait = jnp.minimum(fb / jnp.maximum(demand, 1e-6), 2.0)
    if tables.n_nodes == 0:
        thr = fb / lat
        lat_eff = lat
        hop_total = jnp.float32(0.0)
    else:
        pl = _placement(tables, z, f)
        thr = pl.speed_sum * bf / lat
        lat_eff = lat / pl.min_speed
        n_hops = jnp.sum((pl.primary[:-1] != pl.primary[1:])
                         .astype(jnp.float32))
        hop_total = tables.hop_latency * n_hops
    rho = demand / jnp.maximum(thr, 1e-9)
    congestion = 1.0 / jnp.maximum(1.0 - rho, 0.1)
    return jnp.sum(wait + lat_eff * congestion) + hop_total


def _apply_config(tables: PipelineTables, state: RuntimeState,
                  action: jax.Array) -> RuntimeState:
    """Decode + install one env's configuration at an interval boundary
    (cold start in virtual time, re-placement, telemetry reset) — the first
    half of ``RuntimeEnv.step``."""
    z, f, b = decode_action(tables, action)
    switched = z != state.z
    blocked = jnp.where(switched,
                        jnp.maximum(state.blocked,
                                    state.now + COLD_START_SECONDS),
                        state.blocked)
    slot_speed, hop_next = _install_placement(tables, z, f)
    # in-flight transfers keep the delivery stamps they departed with — a
    # hop re-placement only affects batches completed after it, exactly
    # like the Python runtime's already-heaped transfer events
    return state._replace(z=z, f=f, b=b, blocked=blocked,
                          slot_speed=slot_speed, hop_next=hop_next,
                          completed=jnp.float32(0.0),
                          lat_sum=jnp.float32(0.0))


def _score(tables: PipelineTables, state: RuntimeState, arrived: jax.Array,
           weights: QoSWeights):
    """Score one env's measured interval telemetry with Eq. (3)/(7) — the
    second half of ``RuntimeEnv.step``. Returns (reward, metrics)."""
    w = weights
    z, f, b = state.z, state.f, state.b
    demand = arrived / ADAPTATION_INTERVAL
    T = state.completed / ADAPTATION_INTERVAL
    L = jnp.where(state.completed > 0,
                  state.lat_sum / jnp.maximum(state.completed, 1.0),
                  _analytic_latency(tables, z, f, b,
                                    jnp.maximum(demand, 1.0)))
    E = demand - T
    V = jnp.sum(_gather(tables.accuracy, z))
    C = jnp.sum(_gather(tables.cost, z) * f.astype(jnp.float32))
    qos = (w.alpha * V + w.beta * T - L
           - jnp.where(E >= 0, w.gamma * E, w.delta * (-E)))
    reward = qos - w.beta_c * C - w.gamma_b * jnp.max(b)
    if tables.n_nodes == 0:
        res = _gather(tables.resource, z)
        infeasible = jnp.sum(res * f.astype(jnp.float32)) > tables.w_max
    else:
        infeasible = _placement(tables, z, f).overflow > 0
    reward = reward - 50.0 * infeasible
    metrics = {"qos": qos, "cost": C, "latency": L, "throughput": T,
               "excess": E, "demand": demand,
               "completed": state.completed, "infeasible": infeasible,
               "queue_depths": state.q_len, "backlog": _backlog(state)}
    return reward, metrics


def interval_step(tables: PipelineTables, state: RuntimeState,
                  action: jax.Array, k: jax.Array, ep: EpisodeArrivals,
                  weights: QoSWeights, max_wait: jax.Array):
    """One adaptation interval of the closed loop across the env axis — the
    twin of ``RuntimeEnv.step``: decode + apply each env's configuration,
    advance the shared event loop one interval, score each env's *measured*
    telemetry. ``state``, ``action`` [E, 3N] and ``ep`` carry a leading env
    axis; ``k`` is the shared interval index. Returns (state', rewards [E],
    metrics)."""
    state = jax.vmap(partial(_apply_config, tables))(state, action)
    t1 = (k + 1).astype(jnp.float32) * ADAPTATION_INTERVAL
    state = _advance(tables, state, ep.times, t1, max_wait)
    reward, metrics = jax.vmap(
        lambda st, a: _score(tables, st, a, weights))(state, ep.arrived[:, k])
    return state, reward, metrics


def _backlog(state: RuntimeState) -> jax.Array:
    """Requests admitted but not yet fully served (queued, in cross-node
    transfer, or in flight) — the twin of ``ServingRuntime.in_system``.
    In-transfer batches already sit in their destination queue (stamped
    with a future delivery time), so q_len covers them."""
    in_fl = jnp.sum(jnp.where(state.fl_finish < INF, state.fl_size, 0))
    return (jnp.sum(state.q_len) + in_fl).astype(jnp.float32)


# ------------------------------------------------------------------ rollout --

def rollout(params, tables: PipelineTables, ep: EpisodeArrivals,
            key: jax.Array, *, n_steps: int, weights: QoSWeights,
            max_wait: float = DEFAULT_MAX_WAIT, greedy: bool = False):
    """One on-policy closed-loop episode on the runtime twin — a
    ``vec_rollout`` batch of one. Mirrors ``vecenv.rollout`` so
    ``OPDTrainer`` can swap engines."""
    eps = jax.tree.map(lambda x: x[None], ep)
    traj = vec_rollout(params, tables, eps, key[None], n_steps=n_steps,
                       weights=weights, max_wait=max_wait, greedy=greedy)
    return jax.tree.map(lambda x: x[0], traj)


# NaN + div only: checkify's OOB rule can't transform the batched
# dynamic_update_slice in the vmapped event loop on jax 0.4.x
@sanitize.checked(errors=sanitize.NAN_DIV_ERRORS)
@partial(jax.jit, static_argnames=("n_steps", "weights", "max_wait",
                                   "greedy"))
def vec_rollout(params, tables: PipelineTables, eps: EpisodeArrivals,
                keys: jax.Array, *, n_steps: int, weights: QoSWeights,
                max_wait: float = DEFAULT_MAX_WAIT, greedy: bool = False):
    """Parallel closed-loop episodes via one ``lax.scan`` over the shared
    interval clock: sample each env's action, advance the batched event
    loop, collect PPO trajectories [E, T, ...]. Each env consumes only its
    own arrivals and key, so outputs are permutation-invariant along the
    env axis (the env dimension is explicit rather than vmapped so the
    event loop's while condition stays scalar — see ``_advance``)."""
    mw = jnp.float32(max_wait)
    state0 = jax.vmap(partial(init_state, tables))(eps)

    def obs_of(state, load):
        return jax.vmap(
            lambda z, f, b, l: observe_cfg(tables, z, f, b, l))(
                state.z, state.f, state.b, load)

    obs0 = obs_of(state0, eps.load_obs[:, 0])

    def one_step(carry, k):
        state, obs, kkeys = carry
        split = jax.vmap(jax.random.split)(kkeys)
        kkeys, subs = split[:, 0], split[:, 1]
        action, logp, value = jax.vmap(
            lambda o, s: sample_action(params, o, s, greedy=greedy))(
                obs, subs)
        state, r, metrics = interval_step(tables, state, action, k, eps,
                                          weights, mw)
        load = eps.load_obs[:, jnp.minimum(k + 1, n_steps - 1)]
        obs_next = obs_of(state, load)
        out = {"states": obs, "actions": action, "logps": logp,
               "rewards": r, "values": value, "qos": metrics["qos"],
               "completed": metrics["completed"]}
        return (state, obs_next, kkeys), out

    (_, obs_last, _), traj = jax.lax.scan(
        one_step, (state0, obs0, keys),
        jnp.arange(n_steps, dtype=jnp.int32))
    traj = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), traj)
    _, last_value = apply_policy(params, obs_last)
    traj["last_value"] = last_value
    return traj


# NaN + div only — same OOB-rule limitation as vec_rollout above
@sanitize.checked(errors=sanitize.NAN_DIV_ERRORS)
@partial(jax.jit, static_argnames=("n_steps", "weights", "max_wait"))
def replay(tables: PipelineTables, ep: EpisodeArrivals, actions: jax.Array,
           *, n_steps: int, weights: QoSWeights,
           max_wait: float = DEFAULT_MAX_WAIT):
    """Drive the twin with a fixed action sequence [T, 3N] (policy head
    indices) and return per-interval rewards + measured metrics — the
    equivalence-pinning hook ``tests/test_runtime_vec.py`` compares against
    ``RuntimeEnv`` stepping the same decisions."""
    mw = jnp.float32(max_wait)
    eps = jax.tree.map(lambda x: x[None], ep)
    state0 = jax.vmap(partial(init_state, tables))(eps)

    def one_step(state, ka):
        k, action = ka
        state, r, metrics = interval_step(tables, state, action[None], k,
                                          eps, weights, mw)
        return state, {"rewards": r, **metrics}

    _, out = jax.lax.scan(one_step, state0,
                          (jnp.arange(n_steps, dtype=jnp.int32), actions))
    return jax.tree.map(lambda x: x[:, 0], out)
