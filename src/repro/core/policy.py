"""Multi-discrete actor-critic policy network.

Action a_t = [(z_n, f_n, b_n)]_{n=1..N} (Eq. 6) -> one categorical head per
(task, knob). The feature extractor (residual blocks, features.py) is shared
between the actor heads and the value function. When the pipeline changes,
the head structure is rebuilt to match the new action space (paper: "When
the task changes, the action space must be modified").
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np  # reprolint: ignore[RPL002] host-side action<->config translation only, never under jit

from repro import nn
from repro.core.features import FEATURE_DIM, extract, init_features
from repro.core.mdp import Config, Pipeline


def head_sizes(pipe: Pipeline) -> tuple[int, ...]:
    """Per-task (|Z_n|, F_max, |batch choices|) flattened."""
    sizes = []
    nb = len(pipe.batch_choices())
    for task in pipe.tasks:
        sizes += [len(task.variants), pipe.f_max, nb]
    return tuple(sizes)


def init_policy(key, state_dim: int, sizes: tuple[int, ...]):
    ks = jax.random.split(key, len(sizes) + 2)
    return {
        "features": init_features(ks[0], state_dim),
        "heads": [nn.init_linear(k, FEATURE_DIM, s, bias=True, scale=0.01)
                  for k, s in zip(ks[1:-1], sizes, strict=True)],
        "value": nn.init_linear(ks[-1], FEATURE_DIM, 1, bias=True, scale=0.01),
    }


def apply_policy(params, state):
    """state [B, D] -> (list of logits [B, s_i], value [B])."""
    feats = extract(params["features"], state)
    logits = [nn.linear(h, feats) for h in params["heads"]]
    value = nn.linear(params["value"], feats)[..., 0]
    return logits, value


@partial(jax.jit, static_argnames=("greedy",))
def sample_action(params, state, key, *, greedy: bool = False):
    """state [D] -> (action indices [n_heads], log_prob, value)."""
    logits, value = apply_policy(params, state[None])
    idxs, logps = [], []
    keys = jax.random.split(key, len(logits))
    for lg, k in zip(logits, keys, strict=True):
        lg = lg[0]
        logp = jax.nn.log_softmax(lg)
        idx = jnp.argmax(lg) if greedy else jax.random.categorical(k, lg)
        idxs.append(idx)
        logps.append(logp[idx])
    return jnp.stack(idxs), jnp.stack(logps).sum(), value[0]


def log_prob_entropy(params, states, actions):
    """states [B, D]; actions [B, n_heads] -> (logp [B], entropy [B], value [B])."""
    logits, value = apply_policy(params, states)
    logp_total = 0.0
    ent_total = 0.0
    for i, lg in enumerate(logits):
        logp = jax.nn.log_softmax(lg)
        probs = jnp.exp(logp)
        logp_total = logp_total + jnp.take_along_axis(
            logp, actions[:, i:i + 1], axis=-1)[:, 0]
        ent_total = ent_total - jnp.sum(probs * logp, axis=-1)
    return logp_total, ent_total, value


def action_to_config(pipe: Pipeline, action: np.ndarray) -> Config:
    """Head indices [3N] -> Config, clamped to each task's variant count."""
    bc = pipe.batch_choices()
    z, f, b = [], [], []
    for n, task in enumerate(pipe.tasks):
        zi = int(action[3 * n]) % len(task.variants)
        fi = int(action[3 * n + 1]) + 1
        bi = bc[int(action[3 * n + 2]) % len(bc)]
        z.append(zi)
        f.append(fi)
        b.append(bi)
    return Config(z=tuple(z), f=tuple(f), b=tuple(b))


def config_to_action(pipe: Pipeline, cfg: Config) -> np.ndarray:
    """Inverse of action_to_config (for expert trajectories)."""
    bc = pipe.batch_choices()
    out = []
    for n in range(pipe.n_tasks):
        out += [cfg.z[n], cfg.f[n] - 1, bc.index(cfg.b[n])]
    return np.asarray(out, dtype=np.int32)
