"""The reprolint rule catalogue (RPL001–RPL006).

Each rule mechanises one convention this codebase learned the hard way —
see ``docs/ANALYSIS.md`` for the full catalogue with rationale and fix
recipes, and ``tests/test_analysis.py`` for a caught/clean fixture pair per
rule:

  RPL001  PRNG key reuse (the OPD jit-warmup bug fixed in PR 2)
  RPL002  host-side numerics in jit-pure modules (twin-divergence hazard)
  RPL003  raw version-sensitive ``jax.*`` APIs that bypass ``repro.compat``
  RPL004  spec-safety: ``*Spec`` dataclasses frozen + JSON-round-trip safe
  RPL005  CPU loop-lowering anti-patterns (the PR 5 event-loop lessons)
  RPL006  device→host syncs inside a benchmark's timed region
"""
from __future__ import annotations

import ast
from collections.abc import Iterator

from repro.analysis.framework import (ERROR, WARNING, Rule, SourceModule,
                                      register)

# Modules whose traced code must stay host-free: the jitted twins, the
# policy/PPO jit surface, the measured stage executor, and everything
# models/kernels under jit.
JIT_PURE_FILES = ("core/vecenv.py", "core/runtime_vec.py", "core/ppo.py",
                  "core/policy.py", "cluster/executor.py")
JIT_PURE_DIRS = ("/train/", "/nn/", "/kernels/")

# jax.random callables that *create or derive* keys rather than consume one.
_KEY_MAKERS = frozenset({"PRNGKey", "key", "key_data", "wrap_key_data",
                         "clone", "key_impl", "default_prng_impl"})

# Callables that trace a function handed to them by name.
_TRACE_ENTRY = frozenset({
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.map",
    "jax.lax.associative_scan", "jax.vmap", "jax.pmap", "jax.grad",
    "jax.value_and_grad", "jax.checkpoint", "jax.remat",
})

# Raw API -> the repro.compat shim that must be used instead.
_COMPAT_SHIMS = {
    "jax.shard_map": "repro.compat.shard_map",
    "jax.experimental.shard_map.shard_map": "repro.compat.shard_map",
    "jax.sharding.use_mesh": "repro.compat.use_mesh",
    "jax.set_mesh": "repro.compat.use_mesh",
    "jax.sharding.get_abstract_mesh": "repro.compat.ambient_mesh",
    "jax.interpreters.pxla.thread_resources": "repro.compat.ambient_mesh",
    "jax.sharding.AbstractMesh": "repro.compat.abstract_mesh",
    "jax.make_mesh": "repro.compat.make_mesh",
    "jax.experimental.pallas.tpu.CompilerParams":
        "repro.compat.pallas_tpu_compiler_params",
    "jax.experimental.pallas.tpu.TPUCompilerParams":
        "repro.compat.pallas_tpu_compiler_params",
}

_JSON_ATOMS = frozenset({"str", "int", "float", "bool", "None"})
_JSON_CONTAINERS = frozenset({"tuple", "list", "dict", "Tuple", "List",
                              "Dict", "Optional", "Union"})


def is_jit_pure(path: str) -> bool:
    return (path.endswith(JIT_PURE_FILES)
            or any(d in path for d in JIT_PURE_DIRS))


def _walk_no_functions(node: ast.AST) -> Iterator[ast.AST]:
    """ast.walk that does not descend into nested function/lambda bodies
    (they are separate scopes, analyzed on their own)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
            stack.extend(ast.iter_child_nodes(child))


# --------------------------------------------------------------- RPL001 --

@register
class KeyReuse(Rule):
    """A ``jax.random`` key passed to two calls without an intervening
    re-bind silently correlates the two draws (PR 2 fixed exactly this in
    the OPD jit-warmup). Every use of a key — including ``split`` — consumes
    it; thread the fresh keys forward instead."""
    code = "RPL001"
    name = "prng-key-reuse"
    severity = ERROR
    description = "jax.random key consumed twice without an intervening split"

    def check(self, mod: SourceModule):
        yield from self._scope(mod, self._body(mod.tree))
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._scope(mod, node.body)

    @staticmethod
    def _body(tree: ast.Module) -> list[ast.stmt]:
        return [s for s in tree.body
                if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef))]

    def _scope(self, mod: SourceModule, body: list[ast.stmt]):
        consumed: dict[str, int] = {}
        yield from self._stmts(mod, body, consumed)

    def _stmts(self, mod, stmts, consumed):
        for stmt in stmts:
            yield from self._stmt(mod, stmt, consumed)

    def _stmt(self, mod, stmt, consumed):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return                          # separate scope
        if isinstance(stmt, ast.If):
            yield from self._exprs(mod, stmt.test, consumed)
            yield from self._branches(mod, [stmt.body, stmt.orelse], consumed)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            yield from self._exprs(mod, stmt.iter, consumed)
            loop_state = dict(consumed)
            targets = _assigned_names(stmt.target, mod)
            for t in targets:
                loop_state.pop(t, None)
            inner = dict(loop_state)
            yield from self._stmts(mod, stmt.body, inner)
            # loop-carried reuse: a key consumed in the body that the body
            # (or the loop target) never re-binds is consumed again on the
            # next iteration
            assigned = set(targets) | _assigned_in(stmt.body, mod)
            for name, line in inner.items():
                if name not in loop_state and name not in assigned:
                    yield (line, f"PRNG key {name!r} is consumed on every "
                                 f"loop iteration without being re-split")
            consumed.clear()
            consumed.update(inner)
            yield from self._stmts(mod, stmt.orelse, consumed)
        elif isinstance(stmt, ast.While):
            yield from self._exprs(mod, stmt.test, consumed)
            inner = dict(consumed)
            yield from self._stmts(mod, stmt.body, inner)
            assigned = _assigned_in(stmt.body, mod)
            for name, line in inner.items():
                if name not in consumed and name not in assigned:
                    yield (line, f"PRNG key {name!r} is consumed on every "
                                 f"loop iteration without being re-split")
            consumed.clear()
            consumed.update(inner)
            yield from self._stmts(mod, stmt.orelse, consumed)
        elif isinstance(stmt, ast.Try):
            for block in [stmt.body, stmt.finalbody, stmt.orelse,
                          *[h.body for h in stmt.handlers]]:
                branch = dict(consumed)
                yield from self._stmts(mod, block, branch)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                yield from self._exprs(mod, item.context_expr, consumed)
            yield from self._stmts(mod, stmt.body, consumed)
        else:
            yield from self._exprs(mod, stmt, consumed)
            for name in _assigned_names(stmt, mod):
                consumed.pop(name, None)

    def _branches(self, mod, blocks, consumed):
        """Run each branch on a copy; keep only consumptions common to all
        branches (conservative: never flags across exclusive branches)."""
        results = []
        for block in blocks:
            branch = dict(consumed)
            yield from self._stmts(mod, block, branch)
            results.append(branch)
        keep = set(results[0])
        for r in results[1:]:
            keep &= set(r)
        consumed.clear()
        for name in keep:
            consumed[name] = results[0][name]

    def _exprs(self, mod, node, consumed):
        """Track jax.random consumption inside one statement/expression."""
        shadowed: set[str] = set()
        for sub in ast.walk(node) if not isinstance(node, ast.stmt) else \
                _walk_no_functions(node):
            if isinstance(sub, (ast.ListComp, ast.SetComp, ast.DictComp,
                                ast.GeneratorExp)):
                for gen in sub.generators:
                    shadowed |= _assigned_names(gen.target, mod)
            if not isinstance(sub, ast.Call):
                continue
            fn = mod.resolve(sub.func)
            if not fn or not fn.startswith("jax.random."):
                continue
            if fn.rsplit(".", 1)[1] in _KEY_MAKERS:
                continue
            key = sub.args[0] if sub.args else None
            if key is None:
                for kw in sub.keywords:
                    if kw.arg == "key":
                        key = kw.value
            name = mod.dotted(key) if key is not None else None
            if name is None or name.split(".")[0] in shadowed:
                continue
            if name in consumed:
                yield (sub, f"PRNG key {name!r} reused (already consumed at "
                            f"line {consumed[name]}); split it and use the "
                            f"fresh subkey")
            else:
                consumed[name] = sub.lineno


def _assigned_names(node: ast.AST, mod: SourceModule) -> set[str]:
    """Names (re)bound by a statement or assignment target."""
    out: set[str] = set()
    targets: list[ast.AST] = []
    if isinstance(node, ast.Assign):
        targets = list(node.targets)
    elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
        targets = [node.target]
    elif isinstance(node, (ast.Name, ast.Attribute, ast.Tuple, ast.List,
                           ast.Starred)):
        targets = [node]
    for t in targets:
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                out |= _assigned_names(el, mod)
        elif isinstance(t, ast.Starred):
            out |= _assigned_names(t.value, mod)
        else:
            name = mod.dotted(t)
            if name:
                out.add(name)
    if isinstance(node, ast.stmt):
        for sub in _walk_no_functions(node):
            if isinstance(sub, ast.NamedExpr):
                out |= _assigned_names(sub.target, mod)
    return out


def _assigned_in(body: list[ast.stmt], mod: SourceModule) -> set[str]:
    out: set[str] = set()
    for stmt in body:
        out |= _assigned_names(stmt, mod)
        for sub in _walk_no_functions(stmt):
            if isinstance(sub, ast.stmt):
                out |= _assigned_names(sub, mod)
    return out


# --------------------------------------------------------------- RPL002 --

@register
class HostNumerics(Rule):
    """Host-side numerics inside traced code of a jit-pure module either
    fail at trace time or — worse — silently bake a trace-time constant
    into the compiled twin, diverging it from the Python reference."""
    code = "RPL002"
    name = "host-numerics-in-traced-code"
    severity = ERROR
    description = "host-side numerics in a jit-pure module's traced code"

    def check(self, mod: SourceModule):
        if not is_jit_pure(mod.path):
            return
        # module-level acknowledgment: importing numpy/time into a jit-pure
        # module is legal only for host-side pre/post-processing — demand an
        # inline suppression stating why
        for node in mod.tree.body:
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name in ("numpy", "time"):
                        yield (node, f"jit-pure module imports {a.name!r}; "
                               f"keep host-side use out of traced code and "
                               f"acknowledge with a reprolint suppression")
            elif (isinstance(node, ast.ImportFrom)
                  and node.module in ("numpy", "time")):
                yield (node, f"jit-pure module imports from {node.module!r}; "
                       f"keep host-side use out of traced code and "
                       f"acknowledge with a reprolint suppression")
        for fn in _traced_functions(mod):
            yield from self._check_traced(mod, fn)

    def _check_traced(self, mod: SourceModule, fn: ast.FunctionDef):
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = mod.resolve(node.func)
                if callee and callee.startswith("time."):
                    yield (node, f"host clock call {callee!r} in traced "
                           f"code — wall time is a trace-time constant "
                           f"under jit")
                if (isinstance(node.func, ast.Name)
                        and node.func.id in ("float", "int", "bool")
                        and node.args
                        and not isinstance(node.args[0], ast.Constant)):
                    yield (node, f"host-side {node.func.id}() cast in "
                           f"traced code forces a device sync and fails "
                           f"under jit; use jnp casts/astype")
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item"
                        and not node.args):
                    yield (node, ".item() in traced code pulls the value "
                           "to host; keep it as a traced array")
            elif isinstance(node, ast.Attribute):
                ref = mod.resolve(node)
                if ref and (ref == "numpy" or ref.startswith("numpy.")):
                    yield (node, f"NumPy reference {ref!r} in traced code "
                           f"— np arrays freeze to trace-time constants; "
                           f"use jax.numpy")
            elif isinstance(node, (ast.If, ast.While, ast.IfExp)):
                for sub in ast.walk(node.test):
                    if isinstance(sub, ast.Call):
                        callee = mod.resolve(sub.func)
                        if callee and (callee.startswith("jax.numpy.")
                                       or callee.startswith("jax.lax.")
                                       or callee.startswith("jax.nn.")):
                            yield (node, "Python branch on a traced "
                                   "expression; use jnp.where / lax.cond")
                            break


def _traced_functions(mod: SourceModule) -> Iterator[ast.FunctionDef]:
    """Functions whose bodies run under trace: jit-decorated defs, defs
    handed by name to lax control flow / vmap, and every def nested inside
    one of those."""
    handed: set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            fn = mod.resolve(node.func)
            if fn in _TRACE_ENTRY:
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if isinstance(arg, ast.Name):
                        handed.add(arg.id)

    def is_traced(fn: ast.FunctionDef) -> bool:
        if fn.name in handed:
            return True
        for deco in fn.decorator_list:
            ref = mod.resolve(deco)
            if ref in ("jax.jit", "jit"):
                return True
            if isinstance(deco, ast.Call):
                head = mod.resolve(deco.func)
                if head in ("jax.jit", "jit"):
                    return True
                if head in ("functools.partial", "partial") and any(
                        mod.resolve(a) in ("jax.jit", "jit")
                        for a in deco.args):
                    return True
        return False

    def walk(node: ast.AST, inside: bool):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                traced = inside or is_traced(child)
                if traced:
                    yield child
                yield from walk(child, traced)
            else:
                yield from walk(child, inside)

    yield from walk(mod.tree, False)


# --------------------------------------------------------------- RPL003 --

@register
class CompatBypass(Rule):
    """The mesh/pallas/cost-analysis surface moves between jax releases;
    ``repro.compat`` pins every call site to one bridging module. Raw use
    of the version-sensitive APIs reintroduces the drift PR 1 fixed."""
    code = "RPL003"
    name = "compat-shim-bypass"
    severity = ERROR
    description = "raw version-sensitive jax API bypassing repro.compat"

    def check(self, mod: SourceModule):
        if mod.path.endswith("repro/compat.py"):
            return                      # the shim itself
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    full = f"{node.module}.{a.name}"
                    shim = _COMPAT_SHIMS.get(full)
                    if shim:
                        yield (node, f"import of {full!r} bypasses the "
                               f"compat shim; use {shim}")
            elif isinstance(node, (ast.Attribute, ast.Name)):
                ref = mod.resolve(node)
                shim = _COMPAT_SHIMS.get(ref) if ref else None
                if shim:
                    yield (node, f"raw {ref!r} is version-sensitive; "
                           f"use {shim}")
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "cost_analysis"
                    and not (mod.resolve(node.func) or "").startswith(
                        "repro.compat")):
                yield (node, "Compiled.cost_analysis() returns different "
                       "shapes across jax versions; use "
                       "repro.compat.cost_analysis(compiled)")


# --------------------------------------------------------------- RPL004 --

@register
class SpecSafety(Rule):
    """``*Spec`` dataclasses are the bit-for-bit reproducibility contract
    (PR 2): frozen, JSON-safe fields, ``to_dict``/``from_dict`` round-trip.
    A mutable or non-serializable spec breaks replay-from-JSON silently."""
    code = "RPL004"
    name = "spec-safety"
    severity = ERROR
    description = "*Spec dataclass not frozen / not JSON-round-trip safe"

    def check(self, mod: SourceModule):
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.ClassDef)
                    and node.name.endswith("Spec")):
                continue
            if not self._frozen_dataclass(mod, node):
                yield (node, f"{node.name} must be @dataclass(frozen=True) "
                       f"— specs are immutable reproducibility artifacts")
            methods = {n.name for n in node.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))}
            for required in ("to_dict", "from_dict"):
                if required not in methods:
                    yield (node, f"{node.name} must define {required}() — "
                           f"specs round-trip through JSON")
            for field in node.body:
                if (isinstance(field, ast.AnnAssign)
                        and isinstance(field.target, ast.Name)
                        and not self._json_safe(field.annotation)):
                    ann = ast.unparse(field.annotation)
                    yield (field, f"{node.name}.{field.target.id}: {ann} is "
                           f"not JSON-safe; allowed: str/int/float/bool, "
                           f"tuple/list/dict of those, nested *Spec")

    @staticmethod
    def _frozen_dataclass(mod: SourceModule, node: ast.ClassDef) -> bool:
        for deco in node.decorator_list:
            if isinstance(deco, ast.Call):
                head = mod.resolve(deco.func)
                if head in ("dataclasses.dataclass", "dataclass"):
                    return any(k.arg == "frozen"
                               and isinstance(k.value, ast.Constant)
                               and k.value.value is True
                               for k in deco.keywords)
        return False

    @classmethod
    def _json_safe(cls, ann: ast.AST) -> bool:
        if isinstance(ann, ast.Constant):
            if ann.value is None or ann.value is Ellipsis:
                return True
            if isinstance(ann.value, str):        # stringified annotation
                try:
                    return cls._json_safe(
                        ast.parse(ann.value, mode="eval").body)
                except SyntaxError:
                    return False
            return False
        if isinstance(ann, ast.Name):
            return ann.id in _JSON_ATOMS or ann.id.endswith("Spec")
        if isinstance(ann, ast.Attribute):
            return ann.attr in _JSON_ATOMS or ann.attr.endswith("Spec")
        if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
            return cls._json_safe(ann.left) and cls._json_safe(ann.right)
        if isinstance(ann, ast.Subscript):
            head = ann.value
            name = head.id if isinstance(head, ast.Name) else (
                head.attr if isinstance(head, ast.Attribute) else None)
            if name not in _JSON_CONTAINERS:
                return False
            inner = ann.slice
            elts = inner.elts if isinstance(inner, ast.Tuple) else [inner]
            return all(cls._json_safe(e) for e in elts)
        return False


# --------------------------------------------------------------- RPL005 --

@register
class CpuLoopLowering(Rule):
    """PR 5's hard-won CPU XLA lessons: a vmapped dynamic-index ``.at[i]
    .set(payload)`` lowers to a sequential per-env loop, and
    ``sum(cumprod)`` window math lowers to an O(window²) reduce_window.
    Both have documented fast shapes (see core/runtime_vec.py)."""
    code = "RPL005"
    name = "cpu-loop-lowering"
    severity = WARNING
    description = "CPU loop-lowering anti-pattern (dynamic scatter / " \
                  "reduce-window-shaped math)"

    def check(self, mod: SourceModule):
        if not is_jit_pure(mod.path):
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            # x.at[<dynamic>].set(payload): scatter with a traced index
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "set"
                    and isinstance(node.func.value, ast.Subscript)
                    and isinstance(node.func.value.value, ast.Attribute)
                    and node.func.value.value.attr == "at"
                    and self._dynamic_index(node.func.value.slice)
                    and node.args
                    and not isinstance(node.args[0], ast.Constant)):
                yield (node, "dynamic-index .at[i].set(payload) is a "
                       "batched dynamic-update-slice — vmapped it "
                       "loop-lowers on CPU XLA; pin an index and gather "
                       "at read time instead (see core/runtime_vec.py)")
            # jnp.sum(... cumprod ...): reduce_window-shaped window math
            callee = mod.resolve(node.func)
            if callee in ("jax.numpy.sum", "numpy.sum"):
                for sub in ast.walk(node):
                    if sub is node:
                        continue
                    if isinstance(sub, ast.Call):
                        inner = mod.resolve(sub.func)
                        inner_name = (inner or "").rsplit(".", 1)[-1]
                        attr = (sub.func.attr
                                if isinstance(sub.func, ast.Attribute)
                                else "")
                        if "cumprod" in (inner_name, attr):
                            yield (node, "sum(cumprod(...)) window math "
                                   "lowers to an O(window²) reduce_window "
                                   "on CPU; use argmin on the bool mask "
                                   "(see core/runtime_vec.py)")
                            break

    @staticmethod
    def _dynamic_index(idx: ast.AST) -> bool:
        parts = idx.elts if isinstance(idx, ast.Tuple) else [idx]
        for p in parts:
            if isinstance(p, (ast.Constant, ast.Slice)):
                continue
            if (isinstance(p, ast.UnaryOp)
                    and isinstance(p.operand, ast.Constant)):
                continue
            return True
        return False


# --------------------------------------------------------------- RPL006 --

# Calls that force a device→host sync (and its transfer) onto the clock.
_SYNC_CALLS = frozenset({"numpy.asarray", "numpy.array", "jax.device_get"})
# The shared min-of-k helpers; functions handed to them by name are timed.
_TIMING_HELPERS = ("time_fn", "time_interleaved")


@register
class TimedRegionSync(Rule):
    """A device→host sync (``.item()``, ``np.asarray`` on a device value,
    ``jax.device_get``) inside a benchmark's timed region bills the
    transfer and the forced pipeline flush to the thing being measured.
    Syncs belong outside the clock; inside it, only ``jax.
    block_until_ready`` (what ``repro.timing`` already does) may wait.

    Timed regions are (a) statements between ``t0 = time.perf_counter()``
    and the first statement that reads ``t0`` back, and (b) bodies of
    functions handed by name to ``time_fn`` / ``time_interleaved``."""
    code = "RPL006"
    name = "sync-in-timed-region"
    severity = ERROR
    description = "device→host sync inside a benchmark's timed region"

    def check(self, mod: SourceModule):
        if "benchmarks/" not in mod.path:
            return
        timed_fns = self._handed_to_timers(mod)
        for body in self._stmt_lists(mod.tree):
            yield from self._perf_counter_regions(mod, body)
        for node in ast.walk(mod.tree):
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name in timed_fns):
                for stmt in node.body:
                    yield from self._syncs(mod, stmt)

    @staticmethod
    def _stmt_lists(tree: ast.Module):
        """Every list of statements in the module (module body, function
        bodies, loop/branch/with bodies) — perf_counter windows live
        within one such list."""
        yield tree.body
        for node in ast.walk(tree):
            for attr in ("body", "orelse", "finalbody"):
                block = getattr(node, attr, None)
                if (block and isinstance(block, list)
                        and not isinstance(node, ast.Module)
                        and isinstance(block[0], ast.stmt)):
                    yield block

    def _perf_counter_regions(self, mod: SourceModule, body):
        """Flag syncs between ``t = time.perf_counter()`` and the first
        statement reading ``t`` (the stop-the-clock statement)."""
        i = 0
        while i < len(body):
            started = self._perf_start(mod, body[i])
            i += 1
            if not started:
                continue
            while i < len(body) and not self._reads(mod, body[i], started):
                yield from self._syncs(mod, body[i])
                i += 1

    @staticmethod
    def _perf_start(mod: SourceModule, stmt) -> str | None:
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and isinstance(stmt.value, ast.Call)):
            callee = mod.resolve(stmt.value.func)
            if callee in ("time.perf_counter", "time.monotonic", "time.time"):
                return stmt.targets[0].id
        return None

    @staticmethod
    def _reads(mod: SourceModule, stmt, name: str) -> bool:
        return any(isinstance(n, ast.Name) and n.id == name
                   for n in ast.walk(stmt))

    def _handed_to_timers(self, mod: SourceModule) -> set[str]:
        """Names of module functions passed (anywhere in the argument
        expressions) to the shared timing helpers."""
        defined = {n.name for n in ast.walk(mod.tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        handed: set[str] = set()
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = mod.resolve(node.func) or ""
            if callee.rsplit(".", 1)[-1] not in _TIMING_HELPERS:
                continue
            for arg in list(node.args) + [k.value for k in node.keywords]:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Name) and sub.id in defined:
                        handed.add(sub.id)
        return handed

    def _syncs(self, mod: SourceModule, stmt):
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                yield (node, ".item() inside a timed region forces a "
                       "device→host sync onto the clock; hoist it out of "
                       "the timed window")
            callee = mod.resolve(node.func)
            if callee in _SYNC_CALLS:
                yield (node, f"{callee}() inside a timed region copies "
                       f"device values to host on the clock; move the "
                       f"conversion outside the timed window")
