"""reprolint core: AST lint framework for the repo's twin/spec contracts.

The jitted pure-JAX twins (``core/vecenv.py``, ``core/runtime_vec.py``) stay
bit-equivalent to their Python references only while every PR obeys a pile of
implicit conventions — key hygiene, no host numerics in traced code, compat
shims instead of raw version-sensitive ``jax.*`` APIs, JSON-safe frozen
specs, no CPU loop-lowering anti-patterns. This module is the machinery that
lets ``repro.analysis.rules`` state those conventions as checkable rules:

- ``SourceModule``: a parsed file with import-alias resolution
  (``resolve`` maps ``jnp.sum`` -> ``jax.numpy.sum``) and suppression maps;
- ``Rule`` + ``register``: the rule registry the CLI runs;
- ``run`` / ``analyze_source``: drive rules over paths or inline source.

Suppression syntax (parsed from real COMMENT tokens, so string literals
never suppress anything):

    x = f()              # reprolint: ignore[RPL002] host-side by design
    # reprolint: ignore-file[RPL003] this module IS the compat shim

A line-level ``ignore`` silences the named rules on that line only; an
``ignore-file`` anywhere in the file silences them for the whole file.
Everything here is stdlib-only so the lint gate needs no jax install.
"""
from __future__ import annotations

import ast
import io
import tokenize
from collections.abc import Iterable, Iterator
from dataclasses import asdict, dataclass
from pathlib import Path

ERROR = "error"
WARNING = "warning"

_IGNORE = "reprolint:"


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    rule: str
    severity: str            # "error" | "warning"
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} [{self.severity}] {self.message}")

    def to_dict(self) -> dict:
        return asdict(self)


class SourceModule:
    """A parsed source file plus everything rules need to query it."""

    def __init__(self, path: str, source: str):
        self.path = Path(path).as_posix()
        self.source = source
        self.tree = ast.parse(source, filename=self.path)
        self.aliases = _import_aliases(self.tree)
        self.line_ignores: dict[int, set[str] | None] = {}
        self.file_ignores: set[str] | None = set()
        self._parse_suppressions()

    # ------------------------------------------------------- suppressions --

    def _parse_suppressions(self) -> None:
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.source).readline)
            comments = [(t.start[0], t.string) for t in tokens
                        if t.type == tokenize.COMMENT]
        except tokenize.TokenError:
            comments = []
        for line, text in comments:
            body = text.lstrip("#").strip()
            if not body.startswith(_IGNORE):
                continue
            directive = body[len(_IGNORE):].strip()
            if directive.startswith("ignore-file"):
                codes = _codes(directive[len("ignore-file"):])
                if codes is None or self.file_ignores is None:
                    self.file_ignores = None        # suppress every rule
                else:
                    self.file_ignores |= codes
            elif directive.startswith("ignore"):
                codes = _codes(directive[len("ignore"):])
                if codes is None:
                    self.line_ignores[line] = None
                else:
                    prev = self.line_ignores.get(line, set())
                    self.line_ignores[line] = (None if prev is None
                                               else prev | codes)

    def suppressed(self, code: str, line: int) -> bool:
        if self.file_ignores is None or code in self.file_ignores:
            return True
        at = self.line_ignores.get(line, set())
        return at is None or code in at

    # ------------------------------------------------------- name queries --

    def resolve(self, node: ast.AST) -> str | None:
        """Dotted path of a Name/Attribute chain with the module's import
        aliases expanded: with ``import jax.numpy as jnp``, the expression
        ``jnp.sum`` resolves to ``"jax.numpy.sum"``. Returns None for
        anything that is not a plain dotted chain."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        parts.reverse()
        head = self.aliases.get(parts[0])
        if head is not None:
            parts[0] = head
        return ".".join(parts)

    def dotted(self, node: ast.AST) -> str | None:
        """Dotted source text of a Name/Attribute/const-Subscript chain —
        *without* alias expansion (``self.key`` stays ``self.key``). Used
        where the identity of the expression matters, not what it imports."""
        if isinstance(node, ast.Subscript):
            if isinstance(node.slice, ast.Constant):
                base = self.dotted(node.value)
                return None if base is None else f"{base}[{node.slice.value!r}]"
            return None
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        return ".".join(reversed(parts))


def _codes(text: str) -> set[str] | None:
    """``"[RPL001, RPL002]"`` -> {"RPL001", "RPL002"}; no bracket -> None
    (meaning: every rule)."""
    text = text.strip()
    if not (text.startswith("[") and "]" in text):
        return None
    inner = text[1:text.index("]")]
    return {c.strip() for c in inner.split(",") if c.strip()}


def _import_aliases(tree: ast.Module) -> dict[str, str]:
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            prefix = "." * node.level + node.module
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{prefix}.{a.name}"
    return aliases


# ------------------------------------------------------------------ rules --

class Rule:
    """One lint rule. Subclasses set the class attributes and implement
    ``check``, yielding ``(node_or_line, message)`` pairs; the framework
    stamps code/severity/path and applies suppressions."""
    code = "RPL000"
    name = "rule"
    severity = ERROR
    description = ""

    def check(self, mod: SourceModule) -> Iterator[tuple[ast.AST | int, str]]:
        raise NotImplementedError

    def findings(self, mod: SourceModule) -> Iterator[Finding]:
        for where, message in self.check(mod):
            if isinstance(where, int):
                line, col = where, 0
            else:
                line = getattr(where, "lineno", 1)
                col = getattr(where, "col_offset", 0)
            if not mod.suppressed(self.code, line):
                yield Finding(rule=self.code, severity=self.severity,
                              path=mod.path, line=line, col=col,
                              message=message)


RULES: dict[str, Rule] = {}


def register(cls: type[Rule]) -> type[Rule]:
    RULES[cls.code] = cls()
    return cls


# ----------------------------------------------------------------- driver --

def iter_py_files(paths: Iterable[str | Path]) -> Iterator[Path]:
    for p in paths:
        p = Path(p)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part.startswith(".") or part == "__pycache__"
                           for part in f.parts):
                    yield f
        elif p.suffix == ".py":
            yield p


def analyze_source(source: str, path: str = "<string>",
                   rules: Iterable[Rule] | None = None) -> list[Finding]:
    """Run rules over inline source text (the test-fixture entry point)."""
    mod = SourceModule(path, source)
    out: list[Finding] = []
    for rule in (rules if rules is not None else RULES.values()):
        out.extend(rule.findings(mod))
    return sorted(out, key=lambda f: (f.path, f.line, f.col, f.rule))


def run(paths: Iterable[str | Path],
        rules: Iterable[Rule] | None = None) -> tuple[list[Finding], int]:
    """Lint every ``*.py`` under ``paths``. Returns (findings, n_files).
    Unparseable files surface as RPL000 errors rather than crashes."""
    findings: list[Finding] = []
    n = 0
    for f in iter_py_files(paths):
        n += 1
        try:
            source = f.read_text(encoding="utf-8")
            mod = SourceModule(str(f), source)
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding(
                rule="RPL000", severity=ERROR, path=Path(f).as_posix(),
                line=getattr(e, "lineno", 1) or 1, col=0,
                message=f"could not parse file: {e.__class__.__name__}"))
            continue
        for rule in (rules if rules is not None else RULES.values()):
            findings.extend(rule.findings(mod))
    return sorted(findings,
                  key=lambda fd: (fd.path, fd.line, fd.col, fd.rule)), n
