"""Checkify sanitizer for the jitted twins (the dynamic half of reprolint).

Static rules (RPL001–RPL005) catch contract violations visible in source;
this module catches the ones only visible at run time — NaNs, division by
zero, out-of-bounds gathers — by wrapping the twin entry points
(``vecenv.rollout``/``vec_rollout``, ``runtime_vec.vec_rollout``/``replay``)
in ``jax.experimental.checkify``. Divergence bugs then surface as typed
``JaxRuntimeError``s at the offending op instead of silent reward drift.

Off by default (checkify adds error-state plumbing through every scan and
while_loop). Enable with either:

- the environment flag ``REPRO_CHECKIFY=1`` (also ``true``/``on``/``yes``),
  e.g. for a CI smoke episode; or
- programmatically: ``sanitize.enable()``, ``with sanitize.enabled_scope():``
  or ``Session(..., debug_checkify=True)``.

The programmatic override wins over the environment in both directions.
"""
from __future__ import annotations

import contextlib
import functools
import os

import jax
from jax.experimental import checkify

# NaN production, 0/0, and out-of-bounds gather/scatter indices — the three
# ways a twin quietly stops matching its Python reference.
ERRORS = checkify.nan_checks | checkify.index_checks | checkify.div_checks

# For entry points where the OOB rule cannot be applied (see ``checked``).
NAN_DIV_ERRORS = checkify.nan_checks | checkify.div_checks

ENV_FLAG = "REPRO_CHECKIFY"

_OVERRIDE: bool | None = None


def enabled() -> bool:
    """Is the sanitizer active? Programmatic override first, then env."""
    if _OVERRIDE is not None:
        return _OVERRIDE
    return os.environ.get(ENV_FLAG, "").strip().lower() in (
        "1", "true", "on", "yes")


def enable(on: bool | None = True) -> None:
    """Force the sanitizer on/off; ``enable(None)`` restores env control."""
    global _OVERRIDE
    _OVERRIDE = on


@contextlib.contextmanager
def enabled_scope(on: bool = True):
    """Temporarily force the sanitizer on (or off) for a block."""
    global _OVERRIDE
    prev = _OVERRIDE
    _OVERRIDE = on
    try:
        yield
    finally:
        _OVERRIDE = prev


def checked(fn=None, *, errors=None):
    """Wrap a twin entry point with a checkified twin-of-the-twin.

    When the sanitizer is off (the default) the wrapper is a passthrough —
    the original jitted ``fn`` runs untouched, so production speed is
    unaffected. When on, calls route through a cached
    ``jit(checkify(fn))`` instance and raise ``JaxRuntimeError`` on any
    NaN / div-by-zero / out-of-bounds index anywhere in the episode.

    ``errors`` narrows the check set for functions where part of the
    default instrumentation cannot be applied (on jax 0.4.x, checkify's
    OOB rule fails to transform the batched ``dynamic_update_slice`` in
    the runtime twin's vmapped event loop — those entry points keep
    NaN + div checks and note why inline).

    Works with the twins' calling convention: positional args are arrays,
    keyword args are jit-static (``n_steps``/``weights``/``greedy``/
    ``max_wait``) and become part of the cache key, closure-captured so
    they never flow through checkify's flattening. Nested calls (e.g.
    ``vec_rollout`` vmapping ``rollout``) short-circuit to the raw
    function — only the outermost entry pays for error plumbing.
    """
    if fn is None:
        return functools.partial(checked, errors=errors)
    error_set = ERRORS if errors is None else errors
    cache: dict = {}

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        if not enabled() or not jax.core.trace_state_clean():
            return fn(*args, **kwargs)
        try:
            cache_key = tuple(sorted(kwargs.items()))
            run = cache.get(cache_key)
        except TypeError:               # unhashable static — don't cache
            cache_key = run = None
        if run is None:
            def call(*arrays):
                return fn(*arrays, **kwargs)

            run = jax.jit(checkify.checkify(call, errors=error_set))
            if cache_key is not None:
                cache[cache_key] = run
        err, out = run(*args)
        checkify.check_error(err)       # raises JaxRuntimeError if tripped
        return out

    wrapper.__wrapped__ = fn
    return wrapper
