"""repro.analysis: reprolint static checks + the checkify runtime sanitizer.

The static side (``framework``, ``rules``, ``cli``) is stdlib-only so the
CI lint job can run ``python -m repro.analysis`` without a jax install.
``repro.analysis.sanitize`` (the checkify wiring) imports jax and is
deliberately *not* imported here — import it explicitly where needed.
"""
from repro.analysis import rules  # noqa: F401  (registers the rule set)
from repro.analysis.framework import (ERROR, RULES, WARNING, Finding, Rule,
                                      SourceModule, analyze_source, register,
                                      run)

__all__ = [
    "ERROR",
    "WARNING",
    "Finding",
    "Rule",
    "RULES",
    "SourceModule",
    "analyze_source",
    "register",
    "rules",
    "run",
]
