"""reprolint CLI: ``python -m repro.analysis [paths]``.

Exit code 1 on any error-severity finding; warnings exit 0 unless
``--strict``. Stdlib-only so the CI lint job runs it without jax.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import rules as _rules  # noqa: F401  (registers rules)
from repro.analysis.framework import ERROR, RULES, run


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: static checks for the repo's twin/spec "
                    "contracts (see docs/ANALYSIS.md)")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit findings as a JSON array")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 on warnings too, not just errors")
    parser.add_argument("--select", metavar="CODES",
                        help="comma-separated rule codes to run "
                             "(default: all)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalogue and exit")
    return parser


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for code, rule in sorted(RULES.items()):
            print(f"{code}  {rule.name:<28} [{rule.severity}] "
                  f"{rule.description}")
        return 0

    selected = None
    if args.select:
        codes = {c.strip() for c in args.select.split(",") if c.strip()}
        unknown = codes - RULES.keys()
        if unknown:
            print(f"unknown rule code(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        selected = [RULES[c] for c in sorted(codes)]

    findings, n_files = run(args.paths, rules=selected)

    if args.as_json:
        print(json.dumps([f.to_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.format())
        errors = sum(f.severity == ERROR for f in findings)
        warnings = len(findings) - errors
        print(f"reprolint: {n_files} file(s) checked, "
              f"{errors} error(s), {warnings} warning(s)")

    if any(f.severity == ERROR for f in findings):
        return 1
    if args.strict and findings:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
