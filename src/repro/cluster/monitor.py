"""Prometheus-style monitoring daemon: ring-buffer time series of incoming
load and node/pipeline telemetry (paper §III-A "Monitoring")."""
from __future__ import annotations

from collections import deque

import numpy as np


class Monitor:
    def __init__(self, history: int = 120):
        self.history = history
        self.load = deque(maxlen=history)
        self.metrics = deque(maxlen=history)

    def record(self, load: float, **metrics):
        self.load.append(float(load))
        self.metrics.append(dict(metrics))

    @property
    def valid(self) -> int:
        """Number of *real* measurements in the window. ``load_history``
        left-pads a cold window with a constant — consumers that trained on
        real traces (predictor/forecaster) should fall back to the
        last-observed load until ``valid >= fn.min_history``."""
        return len(self.load)

    def load_history(self) -> np.ndarray:
        """Last ``history`` seconds of load, left-padded with the oldest value."""
        if not self.load:
            return np.zeros(self.history)
        arr = np.array(self.load, dtype=np.float64)
        if len(arr) < self.history:
            arr = np.concatenate([np.full(self.history - len(arr), arr[0]), arr])
        return arr

    def latest(self, key: str, default: float = 0.0) -> float:
        if not self.metrics:
            return default
        return float(self.metrics[-1].get(key, default))
