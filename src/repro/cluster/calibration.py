"""Perf-model calibration: fit measured stage latencies back into the
analytic model's ``(alpha, beta)`` parameters.

``cluster/perf_model.py`` derives every ModelVariant's latency curve
``latency(b) = alpha + beta*b`` from architecture arithmetic against TPU
v5e constants. ``StageExecutor`` (``cluster/executor.py``) measures the
real curve on a device mesh; this module least-squares-fits those
measurements per variant and per device class into a ``CalibrationTable``,
then rebinds a built ``Pipeline`` onto the fitted coefficients
(``calibrate_pipeline``) and a ``ClusterSpec``'s node speeds onto measured
device-class factors (``apply_to_cluster``).

Because ``core.mdp.pipeline_metrics`` — and therefore both envs, the
vecenv/runtime twins, and the fleet runtime — reads latency exclusively
through ``variant.alpha``/``variant.beta``, swapping the coefficients here
propagates measured physics through the entire control stack without
touching any jitted internals. ``PipelineSpec(perf_source="calibrated",
calibration=<name-or-path>)`` is the user-facing switch; the default
``"analytic"`` leaves every existing pinned reward bit-for-bit intact.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core.mdp import Pipeline, Task

# committed bench-smoke baseline doubles as the default calibration artifact
DEFAULT_TABLE_PATH = (Path(__file__).resolve().parents[3]
                      / "experiments" / "results" / "stage_calibration.json")


def fit_alpha_beta(batches, latencies) -> tuple[float, float]:
    """Least-squares fit of ``latency(b) = alpha + beta*b`` from measured
    points, clamped to the model's physical domain (alpha, beta >= 0).

    A single measured point yields ``(latency, 0.0)`` — a flat curve is the
    honest reading of one sample.
    """
    b = np.asarray(batches, dtype=np.float64)
    y = np.asarray(latencies, dtype=np.float64)
    if b.shape != y.shape or b.ndim != 1 or b.size == 0:
        raise ValueError("batches and latencies must be equal-length 1-D")
    if b.size == 1 or np.all(b == b[0]):
        return float(max(y.mean(), 0.0)), 0.0
    beta, alpha = np.polyfit(b, y, 1)
    return float(max(alpha, 0.0)), float(max(beta, 0.0))


def predict(alpha: float, beta: float, batches) -> np.ndarray:
    return alpha + beta * np.asarray(batches, dtype=np.float64)


def mean_relative_error(pred, measured) -> float:
    pred = np.asarray(pred, dtype=np.float64)
    measured = np.asarray(measured, dtype=np.float64)
    return float(np.mean(np.abs(pred - measured) / measured))


@dataclass(frozen=True)
class CalibrationTable:
    """Measured ``(alpha, beta)`` per variant plus device-class speed
    factors — the JSON-round-trip artifact ``stage_calibration`` emits and
    ``PipelineSpec(perf_source="calibrated")`` consumes.

    ``variants`` keys are ModelVariant names (``"<arch>:<quant>"``);
    ``speeds`` maps measured device-class labels (``StageExecutor.
    device_class``) to relative service-rate factors.
    """
    device_class: str
    variants: dict[str, tuple[float, float]]
    speeds: dict[str, float] = field(default_factory=dict)
    meta: dict = field(default_factory=dict)

    @classmethod
    def from_timings(cls, timings, *, speeds: dict | None = None,
                     meta: dict | None = None) -> CalibrationTable:
        """Group executor ``StageTiming``s by variant and fit each measured
        ``latency(b)`` curve. All timings must come from one device class."""
        classes = {t.device_class for t in timings}
        if len(classes) != 1:
            raise ValueError(f"timings span device classes {sorted(classes)};"
                             " fit one table per class")
        curves: dict[str, tuple[list, list]] = {}
        for t in timings:
            bs, ys = curves.setdefault(f"{t.arch}:{t.quant}", ([], []))
            bs.append(t.batch)
            ys.append(t.latency_s)
        variants = {name: fit_alpha_beta(bs, ys)
                    for name, (bs, ys) in sorted(curves.items())}
        return cls(device_class=classes.pop(), variants=variants,
                   speeds=dict(speeds or {}), meta=dict(meta or {}))

    def to_dict(self) -> dict:
        return {"device_class": self.device_class,
                "variants": {k: list(v) for k, v in self.variants.items()},
                "speeds": dict(self.speeds), "meta": dict(self.meta)}

    @classmethod
    def from_dict(cls, d: dict) -> CalibrationTable:
        return cls(device_class=str(d["device_class"]),
                   variants={k: (float(v[0]), float(v[1]))
                             for k, v in d["variants"].items()},
                   speeds={k: float(v)
                           for k, v in d.get("speeds", {}).items()},
                   meta=dict(d.get("meta", {})))

    def save(self, path) -> None:
        Path(path).write_text(json.dumps(self.to_dict(), indent=2) + "\n")

    @classmethod
    def load(cls, path) -> CalibrationTable:
        payload = json.loads(Path(path).read_text())
        # stage_calibration benchmark results embed the table under "table"
        return cls.from_dict(payload.get("table", payload))


def calibrate_pipeline(pipe: Pipeline, table: CalibrationTable) -> Pipeline:
    """The same Pipeline with every variant the table covers rebound onto
    its measured ``(alpha, beta)``; uncovered variants keep their analytic
    coefficients (a partial sweep calibrates what it measured)."""
    tasks = []
    for task in pipe.tasks:
        variants = tuple(
            dataclasses.replace(v, alpha=table.variants[v.name][0],
                                beta=table.variants[v.name][1])
            if v.name in table.variants else v
            for v in task.variants)
        tasks.append(Task(name=task.name, variants=variants))
    return dataclasses.replace(pipe, tasks=tuple(tasks))


def apply_to_cluster(cluster, table: CalibrationTable, class_map: dict):
    """A ClusterSpec with node speed factors replaced by measured ones.

    ``class_map`` maps each ``NodeSpec.device_class`` (e.g. ``"edge-box"``)
    to a measured label in ``table.speeds`` (e.g. ``"cpu2"``); unmapped
    classes keep their declared speed.
    """
    nodes = tuple(
        dataclasses.replace(n, speed=float(table.speeds[class_map[n.device_class]]))
        if n.device_class in class_map else n
        for n in cluster.nodes)
    return dataclasses.replace(cluster, nodes=nodes)


# --------------------------------------------------------------- registry --

_TABLES: dict[str, CalibrationTable] = {}


def register_table(name: str, table: CalibrationTable) -> CalibrationTable:
    _TABLES[name] = table
    return table


def resolve_table(ref: str | None = None) -> CalibrationTable:
    """A calibration reference -> table: a ``register_table`` name, a JSON
    path (raw table or a stage_calibration result payload), or None for the
    committed bench-smoke baseline."""
    if ref is None:
        ref = str(DEFAULT_TABLE_PATH)
    if ref in _TABLES:
        return _TABLES[ref]
    path = Path(ref)
    if path.exists():
        return CalibrationTable.load(path)
    raise KeyError(
        f"unknown calibration table {ref!r}: not a registered name and not "
        f"a JSON file (registered: {sorted(_TABLES)})")
