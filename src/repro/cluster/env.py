"""Edge-cell simulator exposing the paper's MDP (state Eq. 5, action Eq. 6,
reward Eq. 7) as a gym-style environment.

Each step = one 10 s adaptation interval over a 1 Hz workload trace. The
stage latency/throughput physics come from perf_model (analytic v5e roofline
of the real architectures); variant switches pay a cold-start penalty
(container re-pull in the paper, weight re-shard here).
"""
from __future__ import annotations

import numpy as np

from repro.cluster.monitor import Monitor
from repro.core.mdp import (Config, Pipeline, QoSWeights, evaluate,
                            resource_usage)

ADAPTATION_INTERVAL = 10          # seconds between decisions (paper §VI-B)
COLD_START_FRACTION = 0.3         # capacity lost in the interval after a switch


class PipelineEnv:
    def __init__(self, pipe: Pipeline, trace: np.ndarray, *,
                 weights: QoSWeights | None = None, history: int = 120,
                 predictor=None, seed: int = 0):
        self.pipe = pipe
        self.trace = np.asarray(trace, dtype=np.float64)
        self.w = weights or QoSWeights()
        self.monitor = Monitor(history)
        self.predictor = predictor           # callable: load_hist -> predicted
        self.rng = np.random.default_rng(seed)
        self.n_steps = len(self.trace) // ADAPTATION_INTERVAL
        self.reset()

    # ------------------------------------------------------------ state --

    @property
    def state_dim(self) -> int:
        # per task: (u, p, m, l, t, z, f, b, c)  — Eq. (5)
        return self.pipe.n_tasks * 9

    def _observe(self) -> np.ndarray:
        pipe, cfg = self.pipe, self.cfg
        u = (pipe.w_max - resource_usage(pipe, cfg)) / pipe.w_max
        p = self._current_load() / 100.0
        m = self._predicted_load() / 100.0
        rows = []
        for n, task in enumerate(pipe.tasks):
            var = task.variants[cfg.z[n]]
            rows.append([
                u, p, m,
                var.latency(cfg.b[n]),                       # l_n
                var.throughput(cfg.b[n], cfg.f[n]) / 100.0,  # t_n
                cfg.z[n] / max(1, len(task.variants) - 1),
                cfg.f[n] / pipe.f_max,
                cfg.b[n] / pipe.b_max,
                cfg.f[n] * var.cost / pipe.w_max,            # c_n
            ])
        return np.asarray(rows, dtype=np.float32).reshape(-1)

    def _current_load(self) -> float:
        s = self.t * ADAPTATION_INTERVAL
        return float(self.trace[max(0, s - 1)])

    def _predicted_load(self) -> float:
        if self.predictor is not None:
            return float(self.predictor(self.monitor.load_history()))
        return self._current_load()

    # ------------------------------------------------------------- api --

    def default_config(self) -> Config:
        N = self.pipe.n_tasks
        return Config(z=tuple(0 for _ in range(N)),
                      f=tuple(1 for _ in range(N)),
                      b=tuple(1 for _ in range(N)))

    def reset(self) -> np.ndarray:
        self.t = 0
        self.cfg = self.default_config()
        self.monitor = Monitor(self.monitor.history)
        for s in range(min(self.monitor.history, len(self.trace))):
            self.monitor.record(self.trace[s])
        return self._observe()

    def step(self, action: Config):
        """Apply ``action`` for the next adaptation interval."""
        prev = self.cfg
        self.cfg = action
        switched = np.array([action.z[n] != prev.z[n]
                             for n in range(self.pipe.n_tasks)])

        s0 = self.t * ADAPTATION_INTERVAL
        s1 = min(len(self.trace), s0 + ADAPTATION_INTERVAL)
        demand = float(np.mean(self.trace[s0:s1]))

        cold = (COLD_START_FRACTION * switched.sum() / self.pipe.n_tasks
                if switched.any() else 0.0)
        m = evaluate(self.pipe, action, demand, self.w, cold_frac=cold)
        r = m["reward"]
        infeasible = resource_usage(self.pipe, action) > self.pipe.w_max
        if infeasible:
            r -= 50.0

        for s in range(s0, s1):
            self.monitor.record(self.trace[s], qos=m["qos"], cost=m["C"],
                                latency=m["L"], throughput=m["T"],
                                excess=m["E"])

        self.t += 1
        done = self.t >= self.n_steps
        info = {"qos": m["qos"], "cost": m["C"], "latency": m["L"],
                "throughput": m["T"], "excess": m["E"], "demand": demand,
                "processed": m["T"], "capacity": m["capacity"],
                "infeasible": infeasible}
        return self._observe(), float(r), done, info
