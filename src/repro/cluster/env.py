"""Edge-cell environments exposing the paper's MDP (state Eq. 5, action
Eq. 6, reward Eq. 7) as gym-style environments.

Two backends share the MDP plumbing (``_ConfigEnvBase``: observation layout,
default config, predictor hook):

- ``PipelineEnv`` — the analytic simulator: each step = one 10 s adaptation
  interval over a 1 Hz workload trace, physics from perf_model's roofline
  latency curves, cold starts charged as a capacity fraction.
- ``RuntimeEnv``  — the closed-loop adapter over the event-driven
  ``serving.runtime.ServingRuntime``: each step applies the action to the
  live runtime (variant switches pay cold start in *virtual time*), advances
  the event loop one adaptation interval, and scores *measured* telemetry
  (served throughput, end-to-end latency percentiles, queue backlog) with
  the same Eq. (3)/(7) formulas via ``score_measurements``. The predictor
  reads the runtime's per-second arrival history through the same Monitor.
"""
from __future__ import annotations

import time

import numpy as np

from repro.cluster.monitor import Monitor
from repro.core.controller import Observation
from repro.core.mdp import (ADAPTATION_INTERVAL, COLD_START_FRACTION, Config,
                            Pipeline, QoSWeights, accuracy_and_cost,
                            analytic_pipeline_latency, evaluate, placement_for,
                            resource_usage, resources_feasible,
                            score_measurements)


class _ConfigEnvBase:
    """Shared MDP plumbing: Eq. (5) observation, default config, predictor."""

    pipe: Pipeline
    cfg: Config
    monitor: Monitor
    predictor = None                 # callable: load_hist -> predicted load
    forecaster = None                # callable: load_hist -> [H] max loads
    forecast_in_state = False        # append forecast block to Eq. 5 state

    @property
    def state_dim(self) -> int:
        # per task: (u, p, m, l, t, z, f, b, c)  — Eq. (5) — plus, on a
        # heterogeneous topology, one free-capacity fraction per node so the
        # feature extractor sees comprehensive node status, plus (opt-in via
        # ``forecast_in_state``) one predicted max load per forecast horizon
        return self.pipe.n_tasks * (9 + self._n_node_features
                                    + self._n_forecast_features)

    @property
    def _n_node_features(self) -> int:
        return 0 if self.pipe.scalar_pool else self.pipe.topo.n_nodes

    @property
    def _n_forecast_features(self) -> int:
        if self.forecaster is None or not self.forecast_in_state:
            return 0
        return len(self.forecaster.horizons)

    def _forecasts(self) -> np.ndarray | None:
        """Per-horizon predicted max loads ([H]), or None without a
        forecaster. Until the monitor holds a full window of *real*
        measurements the model would see constant left-padding it never
        trained on (``Monitor.valid``) — fall back to the last-observed
        load at every horizon."""
        fc = self.forecaster
        if fc is None:
            return None
        if self.monitor.valid < getattr(fc, "min_history", 0):
            return np.full(len(fc.horizons), self._current_load())
        return np.asarray(fc(self.monitor.load_history()), dtype=np.float64)

    def _at_horizon(self, fc: np.ndarray, horizon: float) -> float:
        """The forecast at the horizon nearest ``horizon`` seconds."""
        hs = self.forecaster.horizons
        return float(fc[int(np.argmin([abs(h - horizon) for h in hs]))])

    def predicted_load_at(self, horizon: float) -> float:
        """Horizon-matched predicted max load: the multi-horizon forecast
        nearest ``horizon`` s when a forecaster is attached, else the
        single-horizon predictor / current load."""
        fc = self._forecasts()
        if fc is None:
            return float(self._predicted_load())
        return self._at_horizon(fc, horizon)

    def _observe(self, cur: float | None = None,
                 pred: float | None = None,
                 fc: np.ndarray | None = None) -> np.ndarray:
        pipe, cfg = self.pipe, self.cfg
        u = (pipe.w_max - resource_usage(pipe, cfg)) / pipe.w_max
        p = (self._current_load() if cur is None else cur) / 100.0
        m = (self._predicted_load() if pred is None else pred) / 100.0
        if self._n_node_features:
            pl = placement_for(pipe, cfg)
            node_free = [(node.capacity - used) / node.capacity
                         for node, used in zip(pipe.topo.nodes,
                                               pl.node_usage,
                                               strict=True)]
        else:
            node_free = []
        if self._n_forecast_features:
            if fc is None:
                fc = self._forecasts()
            fc_feats = [float(v) / 100.0 for v in fc]
        else:
            fc_feats = []
        rows = []
        for n, task in enumerate(pipe.tasks):
            var = task.variants[cfg.z[n]]
            rows.append([
                u, p, m,
                var.latency(cfg.b[n]),                       # l_n
                var.throughput(cfg.b[n], cfg.f[n]) / 100.0,  # t_n
                cfg.z[n] / max(1, len(task.variants) - 1),
                cfg.f[n] / pipe.f_max,
                cfg.b[n] / pipe.b_max,
                cfg.f[n] * var.cost / pipe.w_max,            # c_n
            ] + node_free + fc_feats)
        return np.asarray(rows, dtype=np.float32).reshape(-1)

    def _current_load(self) -> float:
        raise NotImplementedError

    def _predicted_load(self) -> float:
        if self.predictor is not None:
            if self.monitor.valid >= getattr(self.predictor,
                                             "min_history", 0):
                return float(self.predictor(self.monitor.load_history()))
            return self._current_load()  # window still padded — see Monitor
        if self.forecaster is not None:
            fc = self._forecasts()
            return self._at_horizon(fc, ADAPTATION_INTERVAL)
        return self._current_load()

    def observe(self) -> Observation:
        """Public decision-time snapshot for the Controller protocol."""
        cur = float(self._current_load())
        fc = self._forecasts()                 # one forecaster call per obs
        if self.predictor is not None or fc is None:
            pred = float(self._predicted_load())
        else:
            pred = self._at_horizon(fc, ADAPTATION_INTERVAL)
        return Observation(
            state=self._observe(cur, pred, fc), config=self.cfg,
            current_load=cur, predicted_load=pred,
            forecasts=(None if fc is None
                       else tuple(float(v) for v in fc)),
            horizons=(None if self.forecaster is None
                      else tuple(self.forecaster.horizons)))

    def default_config(self) -> Config:
        N = self.pipe.n_tasks
        return Config(z=tuple(0 for _ in range(N)),
                      f=tuple(1 for _ in range(N)),
                      b=tuple(1 for _ in range(N)))


class PipelineEnv(_ConfigEnvBase):
    def __init__(self, pipe: Pipeline, trace: np.ndarray, *,
                 weights: QoSWeights | None = None, history: int = 120,
                 predictor=None, forecaster=None,
                 forecast_in_state: bool = False, seed: int = 0):
        self.pipe = pipe
        self.trace = np.asarray(trace, dtype=np.float64)
        self.w = weights or QoSWeights()
        self.monitor = Monitor(history)
        self.predictor = predictor           # callable: load_hist -> predicted
        self.forecaster = forecaster         # callable: load_hist -> [H]
        self.forecast_in_state = bool(forecast_in_state)
        self.rng = np.random.default_rng(seed)
        self.n_steps = len(self.trace) // ADAPTATION_INTERVAL
        self.reset()

    def _current_load(self) -> float:
        s = self.t * ADAPTATION_INTERVAL
        return float(self.trace[max(0, s - 1)])

    # ------------------------------------------------------------- api --

    def reset(self) -> np.ndarray:
        self.t = 0
        self.cfg = self.default_config()
        self.monitor = Monitor(self.monitor.history)
        for s in range(min(self.monitor.history, len(self.trace))):
            self.monitor.record(self.trace[s])
        return self._observe()

    def step(self, action: Config):
        """Apply ``action`` for the next adaptation interval."""
        prev = self.cfg
        self.cfg = action
        switched = np.array([action.z[n] != prev.z[n]
                             for n in range(self.pipe.n_tasks)])

        s0 = self.t * ADAPTATION_INTERVAL
        s1 = min(len(self.trace), s0 + ADAPTATION_INTERVAL)
        demand = float(np.mean(self.trace[s0:s1]))

        cold = (COLD_START_FRACTION * switched.sum() / self.pipe.n_tasks
                if switched.any() else 0.0)
        m = evaluate(self.pipe, action, demand, self.w, cold_frac=cold)
        r = m["reward"]
        infeasible = not resources_feasible(self.pipe, action)
        if infeasible:
            r -= 50.0

        for s in range(s0, s1):
            self.monitor.record(self.trace[s], qos=m["qos"], cost=m["C"],
                                latency=m["L"], throughput=m["T"],
                                excess=m["E"])

        self.t += 1
        done = self.t >= self.n_steps
        info = {"qos": m["qos"], "cost": m["C"], "latency": m["L"],
                "throughput": m["T"], "excess": m["E"], "demand": demand,
                "processed": m["T"], "capacity": m["capacity"],
                "infeasible": infeasible}
        return self._observe(), float(r), done, info


class RuntimeEnv(_ConfigEnvBase):
    """Closed-loop MDP over the live event-driven runtime.

    Arrivals are admitted up-front from an ``ArrivalProcess`` over
    ``horizon`` virtual seconds; each ``step(action)`` reconfigures the
    runtime (cold start paid in virtual time) and advances the event loop by
    one adaptation interval. Reward terms come from *measured* serving:
    T = completions/s in the interval, L = mean end-to-end latency of those
    completions, E = arrival rate − served rate (backlog growth).
    """

    def __init__(self, pipe: Pipeline, arrivals, *, horizon: int = 120,
                 weights: QoSWeights | None = None, history: int = 120,
                 predictor=None, forecaster=None,
                 forecast_in_state: bool = False,
                 executors: list | None = None,
                 max_wait: float | None = None, seq_len: int = 32,
                 vocab: int = 256, loop=None, rid_base: int = 0):
        # all stochasticity derives from arrivals.seed (arrival times and
        # request tokens) — the env itself is deterministic.  ``loop`` (a
        # serving.runtime.EventLoop) shares the event loop with other envs
        # (multi-tenant fleets; do not reset() a shared-loop env twice —
        # the superseded runtime's events would stay heaped); ``rid_base``
        # offsets request ids so tenants stay distinguishable in telemetry.
        from repro.serving.runtime import DEFAULT_MAX_WAIT
        self.pipe = pipe
        self.arrivals = arrivals
        self.horizon = int(horizon)
        self.w = weights or QoSWeights()
        self.predictor = predictor
        self.forecaster = forecaster
        self.forecast_in_state = bool(forecast_in_state)
        self.executors = executors
        self.max_wait = DEFAULT_MAX_WAIT if max_wait is None else max_wait
        self.seq_len = seq_len
        self.vocab = vocab
        self._loop = loop
        self.rid_base = int(rid_base)
        self.monitor = Monitor(history)
        self.n_steps = max(1, self.horizon // ADAPTATION_INTERVAL)
        self.reset()

    def _current_load(self) -> float:
        return float(self.monitor.load_history()[-1])

    # ------------------------------------------------------------- api --

    def reset(self) -> np.ndarray:
        from repro.serving.runtime import ServingRuntime
        self.t = 0
        self.cfg = self.default_config()
        self.runtime = ServingRuntime.from_pipeline(
            self.pipe, cfg=self.cfg, max_wait=self.max_wait,
            seq_len=self.seq_len, executors=self.executors, loop=self._loop)
        self.submitted = self.runtime.load(self.arrivals, self.horizon,
                                           vocab=self.vocab,
                                           rid_base=self.rid_base)
        # prefill the predictor's history with the t=0 expected rate — the
        # newest slot is what _current_load reads for the first observation
        self.monitor = Monitor(self.monitor.history)
        rate0 = float(self.arrivals.rates(1)[0])
        for _ in range(self.monitor.history):
            self.monitor.record(rate0)
        return self._observe()

    def begin_step(self, action: Config):
        """Apply ``action`` without advancing time. Returns the pending
        interval ``(t0, t1, switched, apply_wall_s)`` for ``finish_step``.
        Split out so a fleet can reconfigure *every* tenant before the
        shared event loop advances any of them through the interval."""
        rt = self.runtime
        self.cfg = action
        t0 = rt.now
        t1 = t0 + ADAPTATION_INTERVAL
        wall0 = time.perf_counter()
        switched = rt.apply_config(
            action, cold_start=COLD_START_FRACTION * ADAPTATION_INTERVAL)
        apply_wall_s = time.perf_counter() - wall0
        return t0, t1, switched, apply_wall_s

    def finish_step(self, pending):
        """Score the interval opened by ``begin_step`` after the event loop
        has advanced past ``t1`` (scores ``self.cfg``)."""
        t0, t1, switched, apply_wall_s = pending
        rt, w, action = self.runtime, self.w, self.cfg

        tel = rt.telemetry
        arrived = tel.arrived_in(t0, t1)
        completed = tel.completed_in(t0, t1)
        demand = arrived / ADAPTATION_INTERVAL
        T = completed / ADAPTATION_INTERVAL
        lat = tel.latencies(t0, t1)
        if lat.size:
            L = float(lat.mean())
        else:
            # nothing finished this interval (cold start / deep queues):
            # charge the analytic stage latency so the penalty stays smooth
            L = analytic_pipeline_latency(self.pipe, action, max(demand, 1.0))
        E = demand - T
        V, C = accuracy_and_cost(self.pipe, action)
        m = score_measurements(V, C, T, L, E, w, max_batch=max(action.b))
        r = m["reward"]
        infeasible = not resources_feasible(self.pipe, action)
        if infeasible:
            r -= 50.0

        # measured per-second arrivals feed the predictor's load history
        for c in tel.load_history(t1, ADAPTATION_INTERVAL):
            self.monitor.record(float(c), qos=m["qos"], cost=m["C"],
                                latency=m["L"], throughput=m["T"],
                                excess=m["E"])

        self.t += 1
        done = self.t >= self.n_steps
        info = {"qos": m["qos"], "cost": m["C"], "latency": m["L"],
                "throughput": m["T"], "excess": m["E"], "demand": demand,
                "processed": completed, "infeasible": infeasible,
                "switched": switched, "migrations": rt.last_migrations,
                "apply_wall_s": apply_wall_s,
                "backlog": rt.in_system,
                "shed": tel.shed_in(t0, t1),
                "queue_depths": rt.queue_depths(),
                "node_utilization": rt.node_utilization(),
                **tel.latency_percentiles(t0=t0, t1=t1)}
        return self._observe(), float(r), done, info

    def step(self, action: Config):
        pending = self.begin_step(action)
        self.runtime.run_until(pending[1])
        return self.finish_step(pending)

    def drain(self) -> dict:
        """Finish all in-flight work after the last interval; final summary."""
        self.runtime.drain()
        return self.runtime.summary()
