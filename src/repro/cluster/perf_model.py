"""Analytic TPU-v5e performance model for the assigned architectures.

Replaces the paper's offline profiling of TensorRT/ONNX variants on GPUs:
each ModelVariant's (alpha, beta) latency curve, chip cost and accuracy proxy
are derived from the architecture's arithmetic (active params, FLOPs/token,
KV bytes/token) against v5e constants. The same constants feed the §Roofline
analysis, so the RL environment's physics and the dry-run cost model agree.
"""
from __future__ import annotations

import math

from repro.core.mdp import ModelVariant, Pipeline, Task
from repro.models.config import ArchConfig

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
HBM_CAP = 16e9            # bytes / chip
EFFICIENCY = 0.55         # sustained fraction of peak (MFU-style derate)
DISPATCH_OVERHEAD = 4e-3  # s, per-batch fixed serving overhead (queue+launch)
TOKENS_PER_REQ = 64       # decode tokens per served request (pipeline hop)


def flops_per_token(cfg: ArchConfig) -> float:
    """Forward FLOPs per generated/processed token ~= 2 * active params."""
    return 2.0 * cfg.active_param_count()


def weight_bytes(cfg: ArchConfig, bytes_per_param: float = 2.0) -> float:
    return cfg.param_count() * bytes_per_param


def chips_for(cfg: ArchConfig, *, bytes_per_param: float = 2.0) -> int:
    """Replica footprint: weights (+ Adam-free serving) must fit HBM with
    ~30% headroom for KV cache and activations."""
    need = weight_bytes(cfg, bytes_per_param) / (HBM_CAP * 0.7)
    return max(1, math.ceil(need))


def variant_from_arch(cfg: ArchConfig, *, quant: str = "bf16",
                      accuracy: float | None = None) -> ModelVariant:
    """Build a serving ModelVariant from an architecture config.

    quant in {bf16, int8, int4} scales bytes (and degrades the accuracy
    proxy) — this mirrors the paper's TensorRT/quantisation variants.
    """
    bpp = {"bf16": 2.0, "int8": 1.0, "int4": 0.5}[quant]
    acc_drop = {"bf16": 0.0, "int8": 0.025, "int4": 0.07}[quant]
    chips = chips_for(cfg, bytes_per_param=bpp)
    fpt = flops_per_token(cfg)
    # A request = TOKENS_PER_REQ decode steps. Each step reads the weights
    # once for the WHOLE batch (memory-bound decode, amortised across b) and
    # pays per-item compute: latency(b) = alpha + beta*b with
    #   alpha = dispatch + K * weight-read time   (per batch)
    #   beta  = K * compute time per token        (per item)
    # -> batching amortises the weight stream, the paper's b knob is a real
    #    throughput/latency trade-off.
    t_mem = weight_bytes(cfg, bpp) / (chips * HBM_BW)
    t_flop = fpt / (chips * PEAK_FLOPS * EFFICIENCY)
    alpha = DISPATCH_OVERHEAD + TOKENS_PER_REQ * t_mem
    beta = TOKENS_PER_REQ * t_flop
    if accuracy is None:
        # monotone-in-active-params proxy, calibrated to ~[0.60, 0.96]
        ap = cfg.active_param_count()
        accuracy = min(0.96, 0.50 + 0.095 * math.log10(max(ap, 1e6) / 1e6))
    accuracy = max(0.3, accuracy - acc_drop)
    return ModelVariant(
        name=f"{cfg.name}:{quant}",
        accuracy=round(accuracy, 4),
        cost=float(chips),
        resource=float(chips),
        alpha=alpha,
        beta=beta,
    )


def make_pipeline(arch_cfgs: list[list[ArchConfig]], *, name: str = "pipeline",
                  f_max: int = 8, b_max: int = 32, w_max: float = 64.0,
                  quants: tuple[str, ...] = ("bf16", "int8", "int4"),
                  topology=None) -> Pipeline:
    """One Task per stage; variants = archs × quantisation levels.
    ``topology`` (a ``cluster.topology.ClusterTopology``; None = homogeneous
    scalar pool of capacity ``w_max``) places stage replicas on nodes."""
    tasks = []
    for i, cfgs in enumerate(arch_cfgs):
        variants = tuple(variant_from_arch(c, quant=q)
                         for c in cfgs for q in quants)
        tasks.append(Task(name=f"stage{i}", variants=variants))
    return Pipeline(name=name, tasks=tuple(tasks), f_max=f_max, b_max=b_max,
                    w_max=w_max, topology=topology)


def default_pipeline() -> Pipeline:
    """The paper-style 4-stage pipeline (e.g. detect -> classify -> caption ->
    summarise), stages backed by assigned archs of increasing size."""
    from repro.configs import ARCHS
    stages = [
        [ARCHS["whisper-small"], ARCHS["xlstm-125m"]],
        [ARCHS["llama3.2-1b"], ARCHS["starcoder2-3b"]],
        [ARCHS["granite-moe-3b-a800m"], ARCHS["zamba2-2.7b"]],
        [ARCHS["granite-3-8b"], ARCHS["llava-next-mistral-7b"]],
    ]
    return make_pipeline(stages, name="edge-4stage", w_max=64.0)
