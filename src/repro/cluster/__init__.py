from repro.cluster.topology import (ClusterTopology, Node, Placement,
                                    PlacementCursor)
from repro.cluster.workloads import make_trace, WORKLOADS
from repro.cluster.perf_model import variant_from_arch, default_pipeline, make_pipeline
from repro.cluster.env import (PipelineEnv, RuntimeEnv, ADAPTATION_INTERVAL,
                               COLD_START_FRACTION)
from repro.cluster.monitor import Monitor
from repro.cluster.calibration import (CalibrationTable, calibrate_pipeline,
                                       apply_to_cluster, fit_alpha_beta,
                                       register_table, resolve_table)
