"""Workload traces (requests/s) — the paper's three regimes (Fig. 4):
steady low, fluctuating, steady high. 1200 s cycles, 1 Hz sampling.
Deterministic per seed (paper: "we fix the seed for all random generators").
"""
from __future__ import annotations

import numpy as np

CYCLE_SECONDS = 1200


def make_trace(kind: str, *, seconds: int = CYCLE_SECONDS, seed: int = 0,
               peak: float = 120.0) -> np.ndarray:
    """Per-second request rate [seconds]."""
    rng = np.random.default_rng(seed)
    t = np.arange(seconds, dtype=np.float64)
    if kind == "steady_low":
        lam = 0.15 * peak + 0.02 * peak * np.sin(2 * np.pi * t / 300)
    elif kind == "steady_high":
        lam = 0.85 * peak + 0.03 * peak * np.sin(2 * np.pi * t / 240)
    elif kind == "fluctuating":
        lam = (0.45 * peak
               + 0.30 * peak * np.sin(2 * np.pi * t / 400)
               + 0.10 * peak * np.sin(2 * np.pi * t / 97))
        # occasional bursts
        bursts = rng.random(seconds) < 0.01
        lam = lam + bursts * rng.uniform(0.2, 0.5, seconds) * peak
    else:
        raise ValueError(f"unknown workload kind {kind!r}")
    noise = rng.normal(0.0, 0.02 * peak, seconds)
    return np.clip(lam + noise, 1.0, None)


WORKLOADS = ("steady_low", "fluctuating", "steady_high")
