"""Heterogeneous multi-node cluster topology and placement scheduling.

The paper's decision problem models *device resource limitations*, but a
single scalar pool (``Pipeline.w_max``) cannot express node-local
bottlenecks, device heterogeneity, or cross-node communication. This module
models the edge cell as a set of :class:`Node` s — each with its own chip
capacity, a speed factor (relative serving rate of its device class) and a
device class label — plus a deterministic placement scheduler that bin-packs
stage replicas onto nodes.

Scheduler (shared semantics with the jitted ``core.vecenv`` twin — both
implementations must take identical discrete decisions):

- stages are placed in pipeline order, replicas one at a time;
- each replica goes to the **first node (declaration order) with enough
  remaining capacity**; if none fits, it is force-placed on the node with
  the most remaining capacity (ties -> lowest index) and the shortfall is
  accumulated as ``overflow`` (the placement is then infeasible, mirroring
  the scalar ``resource_usage > w_max`` penalty);
- a stage's *primary node* is the node hosting most of its replicas
  (ties -> lowest index); adjacent stages with different primary nodes pay
  ``hop_latency`` seconds of cross-node transfer per pipeline traversal.

All capacities and per-replica resources are integral chip counts in
practice, so first-fit comparisons are exact in both float64 (here) and
float32 (vecenv) — the two backends reproduce each other bit-for-bit.

A single node with speed 1.0 and zero hop latency (``trivial`` topology)
reduces exactly to the legacy scalar-pool semantics.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache


@dataclass(frozen=True)
class Node:
    """One edge device: chip capacity, relative speed, device class."""
    name: str
    capacity: float          # chips this node contributes to the pool
    speed: float = 1.0       # service-rate factor (latency scales by 1/speed)
    device_class: str = "edge"


@dataclass(frozen=True)
class Placement:
    """Where a configuration's stage replicas landed."""
    nodes: tuple[tuple[int, ...], ...]   # per stage: node index per replica
    node_usage: tuple[float, ...]        # per node: resource units placed
    overflow: float                      # resource that found no room
    stage_speed_sum: tuple[float, ...]   # Σ node speed over a stage's replicas
    stage_min_speed: tuple[float, ...]   # slowest node hosting the stage
    primary: tuple[int, ...]             # primary node per stage
    n_hops: int                          # adjacent stages on different nodes

    @property
    def feasible(self) -> bool:
        return self.overflow <= 0.0


@dataclass(frozen=True)
class ClusterTopology:
    """A named set of nodes plus the cross-node hop penalty."""
    name: str
    nodes: tuple[Node, ...]
    hop_latency: float = 0.0             # s per adjacent-stage cross-node hop

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def total_capacity(self) -> float:
        return sum(n.capacity for n in self.nodes)

    @property
    def trivial(self) -> bool:
        """True when the topology is semantically the legacy scalar pool:
        one node, unit speed, no hop cost."""
        return (self.n_nodes == 1 and self.nodes[0].speed == 1.0
                and self.hop_latency == 0.0)

    @classmethod
    def homogeneous(cls, w_max: float, *,
                    name: str = "homogeneous") -> ClusterTopology:
        """The paper's single scalar pool as a topology."""
        return cls(name=name, nodes=(Node("edge-0", float(w_max)),))

    # ------------------------------------------------------------ placement --

    def place(self, resources: tuple[float, ...],
              replicas: tuple[int, ...]) -> Placement:
        """Deterministic first-fit of ``replicas[n]`` copies of size
        ``resources[n]`` per stage, stages in order. See module docstring
        for the exact decision rules (mirrored by ``core.vecenv``)."""
        return _place_cached(self, tuple(float(r) for r in resources),
                             tuple(int(f) for f in replicas))

    def cursor(self) -> PlacementCursor:
        return PlacementCursor(self)


@lru_cache(maxsize=1 << 16)
def _place_cached(topo: ClusterTopology, resources: tuple[float, ...],
                  replicas: tuple[int, ...]) -> Placement:
    rem = [n.capacity for n in topo.nodes]
    speeds = [n.speed for n in topo.nodes]
    K = len(rem)
    usage = [0.0] * K
    overflow = 0.0
    stage_nodes, speed_sum, min_speed, primary = [], [], [], []
    for w, f in zip(resources, replicas, strict=True):
        assigned = []
        counts = [0] * K
        for _ in range(f):
            idx = next((k for k in range(K) if rem[k] >= w), None)
            if idx is None:                      # force-place, track shortfall
                idx = max(range(K), key=lambda k: (rem[k], -k))
                take = min(w, rem[idx])
                overflow += w - take
            else:
                take = w
            rem[idx] -= take
            usage[idx] += take
            counts[idx] += 1
            assigned.append(idx)
        stage_nodes.append(tuple(assigned))
        speed_sum.append(sum(speeds[k] for k in assigned))
        min_speed.append(min((speeds[k] for k in assigned), default=1.0))
        primary.append(max(range(K), key=lambda k: (counts[k], -k)))
    n_hops = sum(1 for a, b in zip(primary, primary[1:], strict=False) if a != b)
    return Placement(nodes=tuple(stage_nodes), node_usage=tuple(usage),
                     overflow=overflow, stage_speed_sum=tuple(speed_sum),
                     stage_min_speed=tuple(min_speed), primary=tuple(primary),
                     n_hops=n_hops)


class PlacementCursor:
    """Incremental placement for budget loops (greedy / IPA / expert
    capacity-first starts): place stages one at a time, querying whether the
    next stage's replicas still fit. On a trivial topology this reduces
    exactly to the legacy scalar-budget arithmetic
    (``can_place(w, f) == (f * w <= remaining)``)."""

    def __init__(self, topo: ClusterTopology):
        self.topo = topo
        self.rem = [n.capacity for n in topo.nodes]

    @property
    def remaining(self) -> float:
        return sum(self.rem)

    def _fit(self, w: float, f: int) -> list[int] | None:
        """First-fit ``f`` replicas of size ``w`` on a copy of the current
        remainders; None when any replica fails to fit."""
        rem = list(self.rem)
        out = []
        for _ in range(f):
            idx = next((k for k in range(len(rem)) if rem[k] >= w), None)
            if idx is None:
                return None
            rem[idx] -= w
            out.append(idx)
        return out

    def can_place(self, w: float, f: int, *, reserve: float = 0.0) -> bool:
        """Can ``f`` replicas of size ``w`` be placed while leaving at least
        ``reserve`` total capacity for later stages?"""
        if f * w > self.remaining - reserve:
            return False
        return self._fit(w, f) is not None

    def place(self, w: float, f: int) -> bool:
        """Commit the first-fit assignment. When the replicas do not fit the
        capacity is still consumed (force-placed like the scheduler, clamped
        at zero) and False is returned — mirroring the legacy scalar loop,
        where an infeasible fallback stage exhausted the budget so every
        later stage saw none."""
        fit = self._fit(w, f)
        if fit is not None:
            for k in fit:
                self.rem[k] -= w
            return True
        for _ in range(f):
            idx = max(range(len(self.rem)), key=lambda k: (self.rem[k], -k))
            self.rem[idx] -= min(w, self.rem[idx])
        return False
