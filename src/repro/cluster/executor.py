"""Measured stage execution: run model-zoo variants on a jax device mesh
and time their serving step for real.

This is the sim-to-real layer ROADMAP calls "measured, sharded stage
execution": every latency the controller optimizes comes from the analytic
``(alpha, beta)`` perf model (``cluster/perf_model.py``); the paper
validated against a live Kubernetes cluster. ``StageExecutor`` closes that
gap — it takes an architecture from the model zoo (``configs/`` via
``models/api.py``), lowers its decode serving step jitted + sharded across
a device mesh using the ``distributed/sharding.py`` rules (Pallas
``kernels/`` backing attention when ``backend="flash"``), and measures
per-(arch × batch × quant × mesh) step latency with warmup +
``block_until_ready`` min-of-k timing (``repro.timing``).

Compiled executables are cached in an explicit AOT ``ExecutableCache``
keyed by ``(arch, batch, quant, backend, mesh, seq_len)`` — each serving
step is a fresh closure, so ``jax.jit``'s implicit cache can never hit
across reconfigurations; without this cache recompilation dominates the
wall clock of any measurement sweep. ``cluster/calibration.py`` fits the
measured ``latency(b)`` curves back into per-variant ``(alpha, beta)``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import ARCHS
from repro.distributed import sharding as shd
from repro.launch import hlo_cost
from repro.models import api, steps
from repro.models.config import ArchConfig, InputShape
from repro.timing import time_fn

BACKENDS = ("reference", "flash")     # jnp attention | Pallas kernels
QUANT_BITS = {"int8": 8, "int4": 4}


def default_mesh():
    """A (1, n_devices) ("data", "model") mesh over every local device —
    tensor-parallel serving on whatever this host exposes. CPU CI forces
    multiple host devices via ``--xla_force_host_platform_device_count``."""
    n = len(jax.devices())
    return compat.make_mesh((1, n), ("data", "model"))


def quantize_params(params, quant: str):
    """The serving quantisation axis, executably: ``bf16`` casts weights to
    bfloat16; ``int8``/``int4`` symmetric-fake-quantise each float leaf to
    2^bits levels (stored bfloat16 — the measured backend has no integer
    matmul kernels, and the calibration records that truthfully)."""
    if quant == "bf16":
        return jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    bits = QUANT_BITS[quant]
    qmax = float(2 ** (bits - 1) - 1)

    def q(x):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        scale = jnp.max(jnp.abs(x)) / qmax
        scale = jnp.where(scale == 0.0, 1.0, scale)
        levels = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
        return (levels * scale).astype(jnp.bfloat16)

    return jax.tree.map(q, params)


@dataclass(frozen=True)
class ExecKey:
    """Identity of one compiled stage executable."""
    arch: str
    batch: int
    quant: str
    backend: str
    mesh: tuple[tuple[str, int], ...]
    seq_len: int


@dataclass
class _Entry:
    compiled: object
    compile_s: float
    cost: dict | None = None      # hlo_cost.analyze, computed lazily


@dataclass
class ExecutableCache:
    """AOT executable cache with hit/miss accounting. ``lookups ==
    hits + misses``; a repeated configuration never triggers a recompile."""
    entries: dict = field(default_factory=dict)
    hits: int = 0
    misses: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def get_or_build(self, key: ExecKey, build) -> tuple[_Entry, bool]:
        """-> (entry, was_hit). ``build()`` runs only on a miss."""
        entry = self.entries.get(key)
        if entry is not None:
            self.hits += 1
            return entry, True
        self.misses += 1
        entry = build()
        self.entries[key] = entry
        return entry, False


@dataclass(frozen=True)
class StageTiming:
    """One measured point of a variant's latency curve."""
    arch: str
    batch: int
    quant: str
    backend: str
    device_class: str
    latency_s: float          # min-of-k measured step latency
    compile_s: float          # 0.0 on a cache hit
    cache_hit: bool
    flops: float              # trip-count-aware HLO cost (per device)
    bytes: float


class StageExecutor:
    """Executes model-zoo serving steps on a device mesh and measures them.

    ``smoke=True`` (the CPU default) runs each architecture's reduced
    same-family variant (``ArchConfig.smoke``) so the sweep fits host
    memory; the production launch flips it off on a real accelerator mesh.
    ``cache`` may be shared between executors (e.g. one per mesh shape) so
    a fleet-wide sweep reuses executables across device classes.
    """

    def __init__(self, mesh=None, *, seq_len: int = 32, smoke: bool = True,
                 seed: int = 0, cache: ExecutableCache | None = None):
        self.mesh = mesh if mesh is not None else default_mesh()
        self.seq_len = seq_len
        self.smoke = smoke
        self.seed = seed
        self.cache = cache if cache is not None else ExecutableCache()
        self._params: dict = {}       # (arch, quant, backend) -> placed pytree

    # ----------------------------------------------------------- identity --

    @property
    def n_devices(self) -> int:
        n = 1
        for v in self.mesh.shape.values():
            n *= v
        return n

    @property
    def device_class(self) -> str:
        """Label for calibration tables: platform + mesh width (e.g.
        ``cpu2``) — map it onto ``NodeSpec.device_class`` names via
        ``calibration.apply_to_cluster``."""
        return f"{jax.devices()[0].platform}{self.n_devices}"

    def mesh_key(self) -> tuple[tuple[str, int], ...]:
        return tuple((str(a), int(self.mesh.shape[a]))
                     for a in self.mesh.axis_names)

    def key_for(self, arch: str, batch: int, quant: str = "bf16",
                backend: str = "reference") -> ExecKey:
        return ExecKey(arch=arch, batch=int(batch), quant=quant,
                       backend=backend, mesh=self.mesh_key(),
                       seq_len=self.seq_len)

    # ------------------------------------------------------------- builds --

    def arch_config(self, arch: str, backend: str = "reference") -> ArchConfig:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
        cfg = ARCHS[arch]
        if self.smoke:
            cfg = cfg.smoke()
        return cfg.replace(use_flash=(backend == "flash"))

    def params_for(self, arch: str, quant: str = "bf16",
                   backend: str = "reference"):
        """Init-once, quantise, and place params under the mesh's sharding
        rules (cached — param placement is batch-independent)."""
        pkey = (arch, quant, backend)
        if pkey not in self._params:
            cfg = self.arch_config(arch, backend)
            init_key = jax.random.fold_in(
                jax.random.PRNGKey(self.seed), len(self._params))
            params = quantize_params(api.init_model(init_key, cfg), quant)
            psh = shd.param_shardings(cfg, self.mesh, kind="decode")
            self._params[pkey] = jax.device_put(params, psh)
        return self._params[pkey]

    def _inputs(self, cfg: ArchConfig, shape: InputShape, data_key):
        """Concrete decode-step (batch, cache) placed per the mesh rules."""
        batch = {"tokens": jax.random.randint(
            data_key, (shape.global_batch, 1), 0, cfg.vocab, dtype=jnp.int32)}
        ctx = steps.cache_context(cfg, shape)
        cache = api.init_cache(cfg, shape.global_batch, max(ctx, 1))
        bsh = shd.batch_shardings(cfg, shape, self.mesh)
        csh = shd.cache_shardings(cfg, shape, self.mesh)
        return (jax.device_put(batch, bsh), jax.device_put(cache, csh),
                bsh, csh)

    def compiled_step(self, arch: str, batch: int, quant: str = "bf16",
                      backend: str = "reference"):
        """-> (entry, args, was_hit): the AOT-compiled serving step for one
        configuration plus ready-to-call placed arguments."""
        key = self.key_for(arch, batch, quant, backend)
        cfg = self.arch_config(arch, backend)
        shape = InputShape(name=f"serve_b{batch}", seq_len=self.seq_len,
                           global_batch=batch, kind="decode")
        params = self.params_for(arch, quant, backend)
        data_key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1),
                                      batch)
        batch_in, cache_in, bsh, csh = self._inputs(cfg, shape, data_key)

        def build() -> _Entry:
            step = steps.make_serve_step(cfg, shape)
            psh = shd.param_shardings(cfg, self.mesh, kind="decode")
            with compat.use_mesh(self.mesh):
                t = time_fn(lambda: None, reps=1, warmup=0)  # clock warm-up
                del t
                lowered = jax.jit(step, in_shardings=(psh, bsh, csh)).lower(
                    params, batch_in, cache_in)
                timing = time_fn(lowered.compile, reps=1, warmup=0)
            return _Entry(compiled=timing and lowered.compile(),
                          compile_s=timing.best)

        entry, was_hit = self.cache.get_or_build(key, build)
        return entry, (params, batch_in, cache_in), was_hit

    # -------------------------------------------------------- measurement --

    def cost(self, entry: _Entry) -> dict:
        """Trip-count-aware per-device flops/bytes of a compiled step
        (``launch/hlo_cost.py`` — XLA's own cost_analysis counts scanned
        layer stacks once)."""
        if entry.cost is None:
            entry.cost = hlo_cost.analyze(entry.compiled.as_text())
        return entry.cost

    def measure(self, arch: str, batch: int, quant: str = "bf16",
                backend: str = "reference", *, reps: int = 5,
                warmup: int = 1) -> StageTiming:
        """Min-of-``reps`` measured step latency for one configuration.

        Compilation happens outside the timed region (AOT, cached); each
        timed pass ``block_until_ready``s the step output. The returned
        timing carries the HLO roofline inputs for this executable.
        """
        entry, args, was_hit = self.compiled_step(arch, batch, quant, backend)
        timing = time_fn(lambda: entry.compiled(*args),
                         reps=reps, warmup=warmup)
        cost = self.cost(entry)
        return StageTiming(
            arch=arch, batch=int(batch), quant=quant, backend=backend,
            device_class=self.device_class, latency_s=timing.best,
            compile_s=0.0 if was_hit else entry.compile_s,
            cache_hit=was_hit, flops=float(cost["flops"]),
            bytes=float(cost["bytes"]))

    def measure_curve(self, arch: str, batches, quant: str = "bf16",
                      backend: str = "reference", *, reps: int = 5,
                      warmup: int = 1) -> list[StageTiming]:
        """The variant's measured ``latency(b)`` curve across ``batches`` —
        the calibration fit's input."""
        return [self.measure(arch, b, quant, backend, reps=reps,
                             warmup=warmup) for b in batches]
