from repro.distributed.sharding import (
    dp_axes, param_shardings, batch_shardings, cache_shardings,
    residual_constraint, replicated,
)
