"""Sharding rules: architecture-aware PartitionSpecs for params, batches and
caches on the production mesh (("pod",) "data", "model").

Principles (baseline scheme — the §Perf hillclimb iterates from here):
  * batch  -> ("pod","data")  (pure DP across pods)
  * tensor parallel on "model": MLP d_ff (always divisible for the assigned
    archs), attention heads when n_heads % model == 0, expert dim for MoE
    when n_experts % model == 0 (else the per-expert d_ff), vocab when
    divisible (else the embedding's d_model side — jit input shardings must
    divide evenly, GSPMD padding is not available for arguments)
  * residual stream sequence-sharded on "model" between layers (sequence
    parallelism) for train/prefill
  * decode KV caches sharded on the cache-length axis ("context parallel"
    flash-decode style); SSM/xLSTM recurrent states sharded on heads/state
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import api
from repro.models.config import ArchConfig, InputShape
from repro.models.steps import batch_specs, cache_context


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def replicated(mesh):
    return NamedSharding(mesh, P())


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _leaf_rule(cfg: ArchConfig, M: int, path: str, shape: tuple,
               kind: str = "train") -> tuple:
    """PartitionSpec entries for a layer-local param leaf. ``kind`` selects
    the 100B+ expert strategy: train/prefill gather FSDP-sharded weights at
    the shard_map boundary (amortised over many tokens); decode keeps the
    weights resident, two-axis sharded (E x d_ff), and psums activations."""
    heads_ok = cfg.n_heads % M == 0
    kv_ok = cfg.n_kv % M == 0
    ff_ok = cfg.d_ff % M == 0 if cfg.d_ff else False
    vocab_ok = cfg.vocab % M == 0
    d_inner_ok = (2 * cfg.d_model) % M == 0

    def none(nd):
        return (None,) * nd

    # --- embeddings / head ------------------------------------------------
    if path.endswith("embed/e"):
        return ("model", None) if vocab_ok else (None, "model")
    if path.endswith("pos/e"):
        return (None, "model")
    if path.endswith("lm_head/w"):
        return (None, "model") if vocab_ok else ("model", None)
    if path.endswith("vis_proj/w"):
        return (None, "model")

    # --- attention ---------------------------------------------------------
    if "attn" in path:
        name = path.rsplit("/", 2)[-2]        # .../<proj>/w or /b
        is_cross = "cross_attn" in path
        k_ok = heads_ok if is_cross else kv_ok
        if path.endswith("/w"):
            if name == "wq":
                return (None, "model") if heads_ok else none(2)
            if name in ("wk", "wv"):
                return (None, "model") if k_ok else none(2)
            if name == "wo":
                return ("model", None) if heads_ok else none(2)
        if path.endswith("/b"):
            if name == "wq":
                return ("model",) if heads_ok else none(1)
            if name in ("wk", "wv"):
                return ("model",) if k_ok else none(1)
            return none(1)                    # wo bias

    # --- MoE ----------------------------------------------------------------
    if "experts" in path:
        # expert-parallel whenever E >= M (init pads E to a multiple of 16);
        # far cheaper than slicing each expert's d_ff into M slivers.
        # 100B+ models additionally shard the per-expert matrices over the
        # data axis (FSDP-style weight gathering at the shard_map boundary)
        # so params + ZeRO-1 moments fit HBM.
        e_ok = cfg.n_experts >= M
        big = cfg.param_count() > 1e11
        fsdp = "data" if (big and kind != "decode") else None
        ep2d = "data" if (big and kind == "decode") else None
        if path.endswith("wg") or path.endswith("wu"):     # [E, d, ff]
            if e_ok:
                return ("model", fsdp, ep2d)
            return (None, None, "model") if ff_ok else none(3)
        if path.endswith("wd"):                            # [E, ff, d]
            if e_ok:
                return ("model", fsdp or ep2d, None)
            return (None, "model", None) if ff_ok else none(3)
    if "router" in path:
        return none(len(shape))

    # --- dense MLP -----------------------------------------------------------
    if "mlp" in path or "ff_up" in path or "ff_dn" in path:
        if path.endswith(("wg/w", "wu/w", "w1/w", "ff_up/w")):
            return (None, "model") if ff_ok or "ff_up" in path else none(2)
        if path.endswith(("wd/w", "w2/w", "ff_dn/w")):
            return ("model", None) if ff_ok or "ff_dn" in path else none(2)
        if path.endswith("w1/b"):
            return ("model",) if ff_ok else none(1)
        return none(len(shape))

    # --- mamba ----------------------------------------------------------------
    if "mamba" in path:
        if path.endswith(("in_z/w", "in_x/w")):
            return (None, "model") if d_inner_ok else none(2)
        if path.endswith("out_proj/w"):
            return ("model", None) if d_inner_ok else none(2)
        return none(len(shape))

    # --- xlstm -----------------------------------------------------------------
    if "mlstm" in path:
        if path.endswith("up/w"):
            return (None, "model") if d_inner_ok and M % 2 == 0 else none(2)
        if path.endswith(("wq/w", "wk/w", "wv/w")):
            return (None, "model") if d_inner_ok else none(2)
        if path.endswith("down/w"):
            return ("model", None) if d_inner_ok else none(2)
        return none(len(shape))
    if "slstm" in path:
        hid = int(4 / 3 * cfg.d_model)
        if path.endswith("ff_up/w"):
            return (None, "model") if hid % M == 0 else none(2)
        if path.endswith("ff_dn/w"):
            return ("model", None) if hid % M == 0 else none(2)
        return none(len(shape))

    return none(len(shape))


def _scan_prefix(cfg: ArchConfig, path: str) -> int:
    """Leading stacked-layer dims to skip: layers/ -> 1, mamba_layers/ -> 2
    (xlstm uses a python list so its leaves carry no stacked dim)."""
    if cfg.family == "ssm":
        return 0
    if path.startswith("mamba_layers"):
        return 2
    if path.startswith("layers"):
        return 1
    return 0


def param_shardings(cfg: ArchConfig, mesh, *, multi_pod: bool = False,
                    kind: str = "train"):
    """NamedSharding pytree matching init_model's structure."""
    M = mesh.shape["model"]
    params_shape = jax.eval_shape(lambda k: api.init_model(k, cfg),
                                  jax.random.PRNGKey(0))

    def rule(path, leaf):
        p = _path_str(path)
        pre = _scan_prefix(cfg, p)
        spec = _leaf_rule(cfg, M, p, leaf.shape[pre:], kind)
        full = (None,) * pre + tuple(spec)
        assert len(full) == len(leaf.shape), (p, leaf.shape, full)
        # verify divisibility, fall back to replication otherwise
        for dim, ax in zip(leaf.shape, full, strict=True):
            if ax is not None and dim % mesh.shape[ax] != 0:
                return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*full))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_shardings(cfg: ArchConfig, mesh, *, multi_pod: bool = False):
    """ZeRO-1: Adam moments take the param sharding PLUS the data axis on
    the first still-unsharded dim that divides it. The optimizer state is
    the largest train-time allocation (2x fp32 vs bf16 params = 4x bytes);
    sharding it over data costs one update-gather per step, which GSPMD
    emits at the adamw_update boundary."""
    M = mesh.shape["model"]
    dp = dp_axes(multi_pod)
    params_shape = jax.eval_shape(lambda k: api.init_model(k, cfg),
                                  jax.random.PRNGKey(0))

    def rule(path, leaf):
        p = _path_str(path)
        pre = _scan_prefix(cfg, p)
        spec = list((None,) * pre + tuple(_leaf_rule(cfg, M, p,
                                                     leaf.shape[pre:])))
        # fall back to replicated-base like param_shardings
        for dim, ax in zip(leaf.shape, spec, strict=True):
            if ax is not None and (dim % mesh.shape[ax] != 0
                                   if isinstance(ax, str) else False):
                spec = [None] * len(leaf.shape)
                break
        used = {a for s in spec if s is not None
                for a in ((s,) if isinstance(s, str) else s)}
        free_dp = tuple(a for a in dp if a not in used)
        free_size = 1
        for a in free_dp:
            free_size *= mesh.shape[a]
        if free_dp:
            for i in range(pre, len(spec)):
                if spec[i] is None and leaf.shape[i] % free_size == 0 \
                        and leaf.shape[i] >= free_size:
                    spec[i] = free_dp
                    break
        # validate composite dims
        for dim, ax in zip(leaf.shape, spec, strict=True):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if dim % n != 0:
                return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def batch_shardings(cfg: ArchConfig, shape: InputShape, mesh, *,
                    multi_pod: bool = False):
    dp = dp_axes(multi_pod)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bdim = dp if shape.global_batch % dp_size == 0 else None
    specs = batch_specs(cfg, shape)
    return {k: NamedSharding(mesh, P(bdim, *(None,) * (len(v.shape) - 1)))
            for k, v in specs.items()}


def cache_shardings(cfg: ArchConfig, shape: InputShape, mesh, *,
                    multi_pod: bool = False):
    """Shardings matching init_cache's pytree for decode shapes."""
    M = mesh.shape["model"]
    dp = dp_axes(multi_pod)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    B = shape.global_batch
    bdim = dp if B % dp_size == 0 else None
    ctx = cache_context(cfg, shape)
    cache_shape = jax.eval_shape(
        lambda: api.init_cache(cfg, B, max(ctx, 1)))

    def rule(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        if p.endswith("pos"):
            return NamedSharding(mesh, P(bdim))
        if cfg.family in ("dense", "moe", "vlm"):
            # k/v [L, B, C, kv, hd] — shard cache length ("context parallel")
            spec = [None, bdim, "model" if leaf.shape[2] % M == 0 else None,
                    None, None]
            return NamedSharding(mesh, P(*spec))
        if cfg.family == "audio":
            if p.startswith(("ck", "cv")):     # [L, B, enc, H, hd]
                return NamedSharding(mesh, P(None, bdim, None, None, None))
            return NamedSharding(mesh, P(
                None, bdim, "model" if leaf.shape[2] % M == 0 else None,
                None, None))
        if cfg.family == "hybrid":
            if p.startswith(("k", "v")):       # [G, B, C, kv, hd]
                return NamedSharding(mesh, P(
                    None, bdim, "model" if leaf.shape[2] % M == 0 else None,
                    None, None))
            if p.startswith("ssm"):            # [G, per, B, H, Pd, N]
                spec = [None, None, bdim,
                        "model" if leaf.shape[3] % M == 0 else None, None, None]
                return NamedSharding(mesh, P(*spec))
            return NamedSharding(mesh, P(None, None, bdim,
                                         *(None,) * (nd - 3)))
        if cfg.family == "ssm":
            # per-layer states: [B, H, ...P...] — shard the state dim
            if nd >= 3 and leaf.shape[2] % M == 0:
                return NamedSharding(mesh, P(bdim, None, "model",
                                             *(None,) * (nd - 3)))
            return NamedSharding(mesh, P(bdim, *(None,) * (nd - 1)))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def residual_constraint(cfg: ArchConfig, shape: InputShape, mesh, *,
                        multi_pod: bool = False):
    """shard_h callback: sequence-parallel residual stream between layers."""
    dp = dp_axes(multi_pod)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    M = mesh.shape["model"]
    bdim = dp if shape.global_batch % dp_size == 0 else None
    seq = shape.seq_len
    sdim = "model" if seq % M == 0 else None

    def shard_h(h):
        if h.ndim != 3 or h.shape[1] % M != 0:
            return jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, P(bdim, None, None)))
        return jax.lax.with_sharding_constraint(
            h, NamedSharding(mesh, P(bdim, sdim, None)))

    return shard_h
