"""Min-of-k wall-clock timing for device work — the ONE timing loop every
benchmark and the stage executor share.

Protocol (the CPU-microbenchmark standard):
  * ``warmup`` untimed calls first, so jit compilation and first-touch
    allocation never land inside a timed region;
  * each timed pass calls the function and ``jax.block_until_ready``s the
    result, so asynchronous dispatch cannot end the clock early;
  * ``reps`` timed passes, and the *minimum* is the figure of merit — on a
    shared host the min is the undisturbed run, the mean is the noise.

``time_interleaved`` times several functions in interleaved rounds
(fn_a, fn_b, fn_a, fn_b, ...) so a host-level slowdown lands on every
side of a speedup ratio instead of whichever ran while it lasted.

Device→host syncs (``.item()``, ``np.asarray`` on device values) belong
OUTSIDE the timed callables — reprolint RPL006 enforces this for the
benchmark scripts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax


@dataclass(frozen=True)
class Timing:
    """One function's timing: ``best`` = min seconds per pass across reps."""
    best: float
    times: tuple[float, ...]

    @property
    def mean(self) -> float:
        return sum(self.times) / len(self.times)


def time_interleaved(fns, *, reps: int = 3, warmup: int = 1) -> list[Timing]:
    """Time each callable in ``fns`` over ``reps`` interleaved rounds.

    Each call's return value is ``block_until_ready``-ed inside its timed
    window (a no-op for host-only values). Returns one ``Timing`` per fn,
    in order.
    """
    fns = list(fns)
    if reps < 1:
        raise ValueError("reps must be >= 1")
    for _ in range(warmup):
        for fn in fns:
            jax.block_until_ready(fn())
    walls: list[list[float]] = [[] for _ in fns]
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            walls[i].append(time.perf_counter() - t0)
    return [Timing(best=min(w), times=tuple(w)) for w in walls]


def time_fn(fn, *, reps: int = 3, warmup: int = 1) -> Timing:
    """Min-of-``reps`` timing of one callable (see module docstring)."""
    return time_interleaved([fn], reps=reps, warmup=warmup)[0]
