"""Family-dispatched model API.

    init_model(key, cfg)                      -> params
    forward(params, batch, cfg, ...)          -> (logits, aux)
    init_cache(cfg, batch, context)           -> cache pytree
    decode_step(params, batch, cache, cfg)    -> (logits, new_cache)
"""
from __future__ import annotations

from repro.models import decoder, whisper, zamba, xlstm_lm
from repro.models.config import ArchConfig


def _mod(cfg: ArchConfig):
    if cfg.family == "audio":
        return whisper
    if cfg.family == "hybrid":
        return zamba
    if cfg.family == "ssm":
        return xlstm_lm
    return decoder          # dense | moe | vlm


def init_model(key, cfg: ArchConfig):
    return _mod(cfg).init_model(key, cfg)


def forward(params, batch, cfg: ArchConfig, **kw):
    return _mod(cfg).forward(params, batch, cfg, **kw)


def init_cache(cfg: ArchConfig, batch: int, context: int, **kw):
    return _mod(cfg).init_cache(cfg, batch, context, **kw)


def decode_step(params, batch, cache, cfg: ArchConfig, **kw):
    return _mod(cfg).decode_step(params, batch, cache, cfg, **kw)
