"""xLSTM LM (ssm family): stack of mLSTM blocks with every ``slstm_every``-th
layer an sLSTM block. Only 12 layers — the heterogeneous stack is a Python
loop (HLO stays small; the sequence dimension is scanned inside each block).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import nn
from repro.models.config import ArchConfig


def is_slstm(cfg: ArchConfig, i: int) -> bool:
    return cfg.slstm_every > 0 and (i + 1) % cfg.slstm_every == 0


def init_model(key, cfg: ArchConfig):
    dt = cfg.param_dtype
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_layers, cfg.n_layers)
    layers = []
    for i, k in enumerate(keys):
        if is_slstm(cfg, i):
            layers.append({"ln": nn.init_rmsnorm(cfg.d_model, dtype=dt),
                           "slstm": nn.init_slstm(k, cfg.d_model, cfg.n_heads, dtype=dt)})
        else:
            layers.append({"ln": nn.init_rmsnorm(cfg.d_model, dtype=dt),
                           "mlstm": nn.init_mlstm(k, cfg.d_model, cfg.n_heads, dtype=dt)})
    return {
        "embed": nn.init_embedding(k_emb, cfg.vocab, cfg.d_model, dtype=dt),
        "layers": layers,
        "ln_f": nn.init_rmsnorm(cfg.d_model, dtype=dt),
        "lm_head": nn.init_linear(k_head, cfg.d_model, cfg.vocab, dtype=dt),
    }


def forward(params, batch, cfg: ArchConfig, *, window=None, shard_h=None,
            last_only: bool = False, return_hidden: bool = False):
    h = nn.embedding(params["embed"], batch["tokens"])
    for i, lp in enumerate(params["layers"]):
        if shard_h is not None:
            h = shard_h(h)

        if is_slstm(cfg, i):
            def blk(x, lp=lp):
                return x + nn.slstm_scan(lp["slstm"], nn.rmsnorm(lp["ln"], x),
                                         n_heads=cfg.n_heads)
        else:
            def blk(x, lp=lp):
                # chunkwise form: O(S*chunk) memory instead of O(S^2)
                return x + nn.mlstm_chunkwise(lp["mlstm"], nn.rmsnorm(lp["ln"], x),
                                              n_heads=cfg.n_heads)
        h = jax.checkpoint(blk)(h) if cfg.remat else blk(h)
    if last_only:
        h = h[:, -1:]
    h = nn.rmsnorm(params["ln_f"], h)
    aux = {"lb_loss": jnp.zeros((), jnp.float32), "dropped_frac": jnp.zeros((), jnp.float32)}
    if return_hidden:          # train fuses lm_head into the chunked loss
        return h, aux
    logits = nn.linear(params["lm_head"], h)
    return logits, aux


def init_cache(cfg: ArchConfig, batch: int, context: int, *, dtype=None):
    states = []
    for i in range(cfg.n_layers):
        if is_slstm(cfg, i):
            states.append(nn.make_slstm_state(batch, cfg.d_model, cfg.n_heads))
        else:
            states.append(nn.make_mlstm_state(batch, cfg.d_model, cfg.n_heads))
    return {"states": states, "pos": jnp.zeros((batch,), dtype=jnp.int32)}


def decode_step(params, batch, cache, cfg: ArchConfig, *, ring: bool = False):
    h = nn.embedding(params["embed"], batch["tokens"])
    new_states = []
    for i, (lp, st) in enumerate(zip(params["layers"], cache["states"],
                                     strict=True)):
        if is_slstm(cfg, i):
            y, new = nn.slstm_decode(lp["slstm"], nn.rmsnorm(lp["ln"], h), st,
                                     n_heads=cfg.n_heads)
        else:
            y, new = nn.mlstm_decode(lp["mlstm"], nn.rmsnorm(lp["ln"], h), st,
                                     n_heads=cfg.n_heads)
        h = h + y
        new_states.append(new)
    h = nn.rmsnorm(params["ln_f"], h)
    logits = nn.linear(params["lm_head"], h)
    return logits, {"states": new_states, "pos": cache["pos"] + 1}
