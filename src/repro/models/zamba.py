"""Zamba2-style hybrid (hybrid family): Mamba2 backbone with a SHARED
attention+MLP block applied every ``attn_every`` mamba layers.

Structure: the layer stack is grouped — scan over n_groups groups, each group
= one shared-attention application (weights shared across groups, per-group
KV cache) followed by an inner scan over ``attn_every`` stacked mamba layers.
This keeps HLO O(1) in depth and allocates KV cache only for the attention
applications (9 for the 54-layer config), not all 54 layers.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import nn
from repro.nn.mamba2 import CONV_K
from repro.models.config import ArchConfig


def n_groups(cfg: ArchConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def init_model(key, cfg: ArchConfig):
    dt = cfg.param_dtype
    k_emb, k_shared, k_mlp, k_mamba, k_head = jax.random.split(key, 5)
    G = n_groups(cfg)

    def init_mamba_layer(k):
        return {
            "ln": nn.init_rmsnorm(cfg.d_model, dtype=dt),
            "mamba": nn.init_mamba2(k, cfg.d_model, n_heads=cfg.n_heads,
                                    d_state=cfg.ssm_state, dtype=dt),
        }

    keys = jax.random.split(k_mamba, cfg.n_layers).reshape(G, cfg.attn_every, 2)
    mamba_layers = jax.vmap(jax.vmap(init_mamba_layer))(keys)
    return {
        "embed": nn.init_embedding(k_emb, cfg.vocab, cfg.d_model, dtype=dt),
        "shared": {   # ONE shared attention+MLP block (zamba's weight sharing)
            "ln_attn": nn.init_rmsnorm(cfg.d_model, dtype=dt),
            "attn": nn.init_attention(k_shared, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                      cfg.head_dim, dtype=dt),
            "ln_mlp": nn.init_rmsnorm(cfg.d_model, dtype=dt),
            "mlp": nn.init_mlp(k_mlp, cfg.d_model, cfg.d_ff, kind="swiglu", dtype=dt),
        },
        "mamba_layers": mamba_layers,      # leaves [G, attn_every, ...]
        "ln_f": nn.init_rmsnorm(cfg.d_model, dtype=dt),
        "lm_head": nn.init_linear(k_head, cfg.d_model, cfg.vocab, dtype=dt),
    }


def _shared_block(sp, h, cfg: ArchConfig, *, window=None):
    a, _ = nn.attention_prefill(
        sp["attn"], nn.rmsnorm(sp["ln_attn"], h),
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, window=window, use_flash=cfg.use_flash)
    h = h + a
    return h + nn.mlp(sp["mlp"], nn.rmsnorm(sp["ln_mlp"], h), kind="swiglu")


def forward(params, batch, cfg: ArchConfig, *, window=None, shard_h=None,
            last_only: bool = False, return_hidden: bool = False):
    h = nn.embedding(params["embed"], batch["tokens"])
    sp = params["shared"]

    def group_body(carry, group_params):
        hh = carry
        if shard_h is not None:
            hh = shard_h(hh)
        hh = _shared_block(sp, hh, cfg, window=window)

        def mamba_body(c, lp):
            y = nn.mamba2_scan(lp["mamba"], nn.rmsnorm(lp["ln"], c),
                               n_heads=cfg.n_heads, d_state=cfg.ssm_state)
            return c + y, None

        hh, _ = jax.lax.scan(mamba_body, hh, group_params)
        if shard_h is not None:
            hh = shard_h(hh)
        return hh, None

    if cfg.remat:
        group_body = jax.checkpoint(group_body)
    h, _ = jax.lax.scan(group_body, h, params["mamba_layers"])
    if last_only:
        h = h[:, -1:]
    h = nn.rmsnorm(params["ln_f"], h)
    aux = {"lb_loss": jnp.zeros((), jnp.float32), "dropped_frac": jnp.zeros((), jnp.float32)}
    if return_hidden:          # train fuses lm_head into the chunked loss
        return h, aux
    logits = nn.linear(params["lm_head"], h)
    return logits, aux


def init_cache(cfg: ArchConfig, batch: int, context: int, *, dtype=None):
    dt = dtype or cfg.param_dtype
    G = n_groups(cfg)
    sh = (G, batch, context, cfg.n_kv, cfg.head_dim)
    d_inner = 2 * cfg.d_model
    P = d_inner // cfg.n_heads
    return {
        # distinct buffers per leaf (the serve step donates the cache)
        "k": jnp.zeros(sh, dtype=dt), "v": jnp.zeros(sh, dtype=dt),
        "ssm": jnp.zeros((G, cfg.attn_every, batch, cfg.n_heads, P, cfg.ssm_state),
                         dtype=jnp.float32),
        "conv": jnp.zeros((G, cfg.attn_every, batch, CONV_K - 1,
                           d_inner + 2 * cfg.ssm_state), dtype=dt),
        "pos": jnp.zeros((batch,), dtype=jnp.int32),
    }


def decode_step(params, batch, cache, cfg: ArchConfig, *, ring: bool = False):
    h = nn.embedding(params["embed"], batch["tokens"])
    sp = params["shared"]
    pos = cache["pos"]

    def group_body(carry, xs):
        hh = carry
        gp, ck, cv, ssm, conv = xs
        layer_cache = {"k": ck, "v": cv, "pos": pos}
        a, new_c = nn.attention_decode(
            sp["attn"], nn.rmsnorm(sp["ln_attn"], hh), layer_cache,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, ring=ring, use_flash=cfg.use_flash)
        hh = hh + a
        hh = hh + nn.mlp(sp["mlp"], nn.rmsnorm(sp["ln_mlp"], hh), kind="swiglu")

        def mamba_body(c, xs2):
            lp, st_ssm, st_conv = xs2
            y, new_st = nn.mamba2_decode(
                lp["mamba"], nn.rmsnorm(lp["ln"], c),
                {"ssm": st_ssm, "conv": st_conv},
                n_heads=cfg.n_heads, d_state=cfg.ssm_state)
            return c + y, (new_st["ssm"], new_st["conv"])

        hh, (new_ssm, new_conv) = jax.lax.scan(mamba_body, hh, (gp, ssm, conv))
        return hh, (new_c["k"], new_c["v"], new_ssm, new_conv)

    h, (ks, vs, ssms, convs) = jax.lax.scan(
        group_body, h,
        (params["mamba_layers"], cache["k"], cache["v"], cache["ssm"], cache["conv"]))
    h = nn.rmsnorm(params["ln_f"], h)
    logits = nn.linear(params["lm_head"], h)
    return logits, {"k": ks, "v": vs, "ssm": ssms, "conv": convs, "pos": pos + 1}
