"""Architecture configuration and the input-shape suite."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 0          # llama4: MoE every k-th layer (others dense)
    # ssm / hybrid
    ssm_state: int = 0
    attn_every: int = 0         # zamba2: shared attention block every k mamba layers
    # xlstm
    slstm_every: int = 0        # every k-th layer is sLSTM (others mLSTM)
    # modality frontends (stubs — embeddings provided by input_specs)
    enc_len: int = 0            # whisper: encoder frames
    n_patches: int = 0          # vlm: vision patch embeddings
    # flavour
    mlp_kind: str = "swiglu"
    norm: str = "rmsnorm"
    rope_theta: float | None = 10000.0
    window: int | None = None   # sliding-window attention (long-context decode variant)
    dtype: str = "float32"
    remat: bool = True
    use_flash: bool = False     # route attention through the Pallas kernels

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> ArchConfig:
        return dataclasses.replace(self, **kw)

    def smoke(self) -> ArchConfig:
        """Reduced variant of the same family for CPU smoke tests."""
        d = min(self.d_model, 256)
        heads = 4
        kv = min(self.n_kv, heads)
        kw = dict(
            n_layers=2, d_model=d, n_heads=heads, n_kv=kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512), dtype="float32", remat=False,
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2))
        if self.attn_every:
            kw.update(attn_every=1, n_layers=2)
        if self.enc_len:
            kw.update(enc_len=16)
        if self.n_patches:
            kw.update(n_patches=8)
        if self.window:
            kw.update(window=16)
        return self.replace(**kw)

    def param_count(self) -> float:
        """Approximate parameter count (used for 6ND model-flops)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) + (self.n_heads * hd) * d
        if self.family == "ssm":         # xlstm: mixture of mLSTM/sLSTM blocks
            per_layer = 2 * d * 4 * d + 4 * d * d // 2   # rough
        elif self.family == "hybrid":
            d_in = 2 * d
            per_layer = d * (2 * d_in + 2 * self.ssm_state + 32) + d_in * d
        else:
            per_layer = attn
        mlp_total = 0.0
        if self.n_experts:
            n_moe = L // self.moe_every if self.moe_every > 1 else L
            mlp_total += n_moe * (self.n_experts * 3 * d * self.d_ff
                                  + d * self.n_experts)
            if self.moe_every > 1 and self.d_ff:     # interleaved dense layers
                mlp_total += (L - n_moe) * 3 * d * self.d_ff
        elif self.d_ff:
            mult = 3 if self.mlp_kind == "swiglu" else 2
            mlp_total += L * mult * d * self.d_ff
        emb = self.vocab * d * 2
        if self.family == "audio":       # cross-attention adds ~one attn per layer
            per_layer += attn
        return L * per_layer + mlp_total + emb

    def active_param_count(self) -> float:
        """Active params per token (MoE counts only top_k experts)."""
        if not self.n_experts:
            return self.param_count()
        n_moe = (self.n_layers // self.moe_every if self.moe_every > 1
                 else self.n_layers)
        expert_all = n_moe * self.n_experts * 3 * self.d_model * self.d_ff
        dense = self.param_count() - expert_all
        return dense + n_moe * self.top_k * 3 * self.d_model * self.d_ff


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# long-context decode uses a ring-buffer window cache for attention archs
LONG_WINDOW = 8_192
