"""Step-function factories (train / prefill / serve) and abstract input specs.

The same factories serve the CPU smoke tests (concrete arrays) and the
multi-pod dry-run (ShapeDtypeStructs + shardings via jax.jit lower/compile).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import api, whisper
from repro.models.config import ArchConfig, InputShape, LONG_WINDOW
from repro.train import (adamw_update, chunked_lm_head_loss,
                         clip_by_global_norm)


# --------------------------------------------------------------- specs ----

def _f(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def text_len(cfg: ArchConfig, seq_len: int) -> int:
    return seq_len - cfg.n_patches if cfg.family == "vlm" else seq_len


def batch_specs(cfg: ArchConfig, shape: InputShape):
    """ShapeDtypeStructs for the step's ``batch`` argument."""
    B, S = shape.global_batch, shape.seq_len
    act_dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        specs = {"tokens": _f((B, 1), jnp.int32)}
    else:
        specs = {"tokens": _f((B, text_len(cfg, S)), jnp.int32)}
        if cfg.family == "vlm":
            specs["vision_embeds"] = _f((B, cfg.n_patches, cfg.d_model), act_dt)
        if cfg.family == "audio":
            # decode reads the cross-attention KV from the cache instead
            specs["enc_states"] = _f((B, cfg.enc_len, cfg.d_model), act_dt)
    if shape.kind == "train":
        specs["labels"] = _f((B, S), jnp.int32)
    return specs


def cache_specs(cfg: ArchConfig, shape: InputShape):
    """Abstract KV/state cache for decode shapes (context already consumed)."""
    assert shape.kind == "decode"
    context = cache_context(cfg, shape)
    cache = jax.eval_shape(partial(api.init_cache, cfg, shape.global_batch, context))
    return cache


def cache_context(cfg: ArchConfig, shape: InputShape) -> int:
    """Attention-cache length: full context, or ring window for long decode."""
    if cfg.family in ("ssm",):
        return 0                                    # pure recurrent state
    if shape.seq_len > 65_536:
        return LONG_WINDOW                          # ring-buffer sliding window
    return shape.seq_len


def uses_ring(cfg: ArchConfig, shape: InputShape) -> bool:
    return shape.kind == "decode" and cfg.family != "ssm" and shape.seq_len > 65_536


# --------------------------------------------------------------- steps ----

def make_train_step(cfg: ArchConfig, *, lr: float = 3e-4, shard_h=None,
                    microbatch: int | None = None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatch`` = number of gradient-accumulation chunks: activations for
    only one chunk are live at a time (a lax.scan over chunks), cutting peak
    activation memory ~microbatch-fold for large models."""

    def loss_fn(params, batch):
        # lm_head is fused into the sequence-chunked loss so the [B, S, V]
        # logits tensor never materialises (13-33 GB/device at S=4k).
        # labels are [B, S_total]; vision positions carry -100 (set by the
        # data pipeline) so VLM prefix tokens are ignored by the loss.
        h, aux = api.forward(params, batch, cfg, shard_h=shard_h,
                             return_hidden=True)
        loss, metrics = chunked_lm_head_loss(params["lm_head"], h,
                                             batch["labels"],
                                             lb_loss=aux["lb_loss"])
        return loss, metrics

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        if microbatch and microbatch > 1 and B % microbatch == 0:
            mbs = jax.tree.map(
                lambda t: t.reshape(microbatch, B // microbatch, *t.shape[1:]),
                batch)

            def acc(carry, mb):
                loss_s, grads_s = carry
                (loss, metrics), grads = grads_of(params, mb)
                grads_s = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_s, grads)
                return (loss_s + loss, grads_s), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), metrics = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), g0), mbs)
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grads_of(params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, shard_h=None):
    """(params, batch) -> (last-token logits, populated cache or aux)."""

    def prefill_step(params, batch):
        if cfg.family == "audio":
            logits, aux = whisper.forward(params, batch, cfg, shard_h=shard_h)
            cache = whisper.prefill_cache(params, batch, cfg,
                                          batch["tokens"].shape[1])
            return logits[:, -1], cache
        if cfg.family in ("dense", "moe", "vlm"):
            from repro.models import decoder
            logits, aux, cache = decoder.forward(params, batch, cfg,
                                                 shard_h=shard_h, collect_cache=True)
            return logits[:, -1], cache
        # ssm/hybrid prefill: forward only (states would come from scan carries)
        logits, aux = api.forward(params, batch, cfg, shard_h=shard_h)
        return logits[:, -1], aux

    return prefill_step


def make_serve_step(cfg: ArchConfig, shape: InputShape):
    """(params, batch, cache) -> (logits [B, 1, V], new_cache)."""
    ring = uses_ring(cfg, shape)
    window = LONG_WINDOW if ring else None
    dec_cfg = cfg.replace(window=window) if ring else cfg

    def serve_step(params, batch, cache):
        return api.decode_step(params, batch, cache, dec_cfg, ring=ring)

    return serve_step


def make_step(cfg: ArchConfig, shape: InputShape, **kw):
    if shape.kind == "train":
        return make_train_step(cfg, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, **kw)
    return make_serve_step(cfg, shape)
