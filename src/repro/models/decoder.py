"""Decoder-only LM covering dense / moe / vlm families.

Layer stack is scanned (stacked params, lax.scan) so HLO size and trace time
are O(1) in depth — required for the 95-layer deepseek-67b dry-run.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import nn
from repro.models.config import ArchConfig


def _norm_fns(cfg: ArchConfig):
    if cfg.norm == "layernorm":
        return (lambda d, dtype: nn.init_layernorm(d, dtype=dtype)), nn.layernorm
    return (lambda d, dtype: nn.init_rmsnorm(d, dtype=dtype)), nn.rmsnorm


def _block_k(cfg: ArchConfig) -> int:
    """Layers per scanned block: >1 when MoE is interleaved (llama4's
    interleave_moe_layer_step — sub-layers 0..k-2 dense, k-1 MoE)."""
    return cfg.moe_every if (cfg.n_experts and cfg.moe_every > 1) else 1


def init_layer(key, cfg: ArchConfig, use_moe: bool | None = None):
    dt = cfg.param_dtype
    init_norm, _ = _norm_fns(cfg)
    k1, k2 = jax.random.split(key)
    if use_moe is None:
        use_moe = cfg.n_experts > 0
    p = {
        "ln_attn": init_norm(cfg.d_model, dt),
        "attn": nn.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                  cfg.head_dim, dtype=dt,
                                  qkv_bias=cfg.norm == "layernorm"),
        "ln_mlp": init_norm(cfg.d_model, dt),
    }
    if use_moe:
        p["moe"] = nn.init_moe(k2, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype=dt)
    else:
        p["mlp"] = nn.init_mlp(k2, cfg.d_model, cfg.d_ff, kind=cfg.mlp_kind, dtype=dt)
    return p


def init_model(key, cfg: ArchConfig):
    dt = cfg.param_dtype
    init_norm, _ = _norm_fns(cfg)
    k_emb, k_layers, k_head, k_vis = jax.random.split(key, 4)
    k = _block_k(cfg)
    if k == 1:
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        layers = jax.vmap(partial(init_layer, cfg=cfg))(layer_keys)
    else:
        assert cfg.n_layers % k == 0

        def init_block(bkey):
            ks = jax.random.split(bkey, k)
            return {f"sub{i}": init_layer(ks[i], cfg, use_moe=(i == k - 1))
                    for i in range(k)}

        layers = jax.vmap(init_block)(
            jax.random.split(k_layers, cfg.n_layers // k))
    params = {
        "embed": nn.init_embedding(k_emb, cfg.vocab, cfg.d_model, dtype=dt),
        "layers": layers,
        "ln_f": init_norm(cfg.d_model, dt),
        "lm_head": nn.init_linear(k_head, cfg.d_model, cfg.vocab, dtype=dt),
    }
    if cfg.family == "vlm":
        # projector stub: vision embeddings arrive pre-projected at d_model;
        # a learned gate keeps the projector a real (if tiny) parameter.
        params["vis_proj"] = nn.init_linear(k_vis, cfg.d_model, cfg.d_model, dtype=dt)
    return params


def embed_inputs(params, batch, cfg: ArchConfig):
    """tokens [B, S] (+ optional vision_embeds [B, P, d]) -> h [B, S_total, d]."""
    h = nn.embedding(params["embed"], batch["tokens"])
    if cfg.family == "vlm":
        vis = nn.linear(params["vis_proj"], batch["vision_embeds"].astype(h.dtype))
        h = jnp.concatenate([vis, h], axis=1)
    return h


def forward(params, batch, cfg: ArchConfig, *, window=None, shard_h=None,
            collect_cache: bool = False, last_only: bool = False,
            return_hidden: bool = False):
    """Full-sequence forward -> (logits, aux[, cache]). Train and prefill.
    ``last_only`` computes logits for the final position only (prefill does
    not need the [B, S, vocab] tensor)."""
    h = embed_inputs(params, batch, cfg)
    S_total = h.shape[1]
    _, norm = _norm_fns(cfg)
    kblk = _block_k(cfg)

    def one_layer(lp, hh, use_moe: bool):
        a, (k, v) = nn.attention_prefill(
            lp["attn"], norm(lp["ln_attn"], hh),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, window=window, use_flash=cfg.use_flash)
        hh = hh + a
        if use_moe:
            m, aux = nn.moe(lp["moe"], norm(lp["ln_mlp"], hh), top_k=cfg.top_k)
        else:
            m = nn.mlp(lp["mlp"], norm(lp["ln_mlp"], hh), kind=cfg.mlp_kind)
            aux = {"lb_loss": jnp.zeros((), jnp.float32),
                   "dropped_frac": jnp.zeros((), jnp.float32)}
        return hh + m, aux, (k, v)

    def body(carry, lp):
        hh = carry
        if shard_h is not None:
            hh = shard_h(hh)
        if kblk == 1:
            hh, aux, kv = one_layer(lp, hh, cfg.n_experts > 0)
        else:
            auxs_, ks_, vs_ = [], [], []
            for i in range(kblk):
                hh, aux_i, (k_i, v_i) = one_layer(lp[f"sub{i}"], hh,
                                                  use_moe=(i == kblk - 1))
                auxs_.append(aux_i)
                ks_.append(k_i)
                vs_.append(v_i)
            aux = jax.tree.map(lambda *x: jnp.stack(x).mean(), *auxs_)
            kv = (jnp.stack(ks_), jnp.stack(vs_))     # [kblk, B, S, kv, hd]
        if shard_h is not None:
            hh = shard_h(hh)
        ys = (aux, kv) if collect_cache else (aux, None)
        return hh, ys

    if cfg.remat:
        body = jax.checkpoint(body)
    h, (auxs, kvs) = jax.lax.scan(body, h, params["layers"])
    if last_only:
        h = h[:, -1:]
    h = norm(params["ln_f"], h)
    aux = jax.tree.map(jnp.mean, auxs)
    if return_hidden:          # train fuses lm_head into the chunked loss
        return h, aux
    logits = nn.linear(params["lm_head"], h)
    if collect_cache:
        ks, vs = kvs
        if kblk > 1:      # [n_blocks, kblk, B, S, kv, hd] -> [L, ...]
            ks = ks.reshape(cfg.n_layers, *ks.shape[2:])
            vs = vs.reshape(cfg.n_layers, *vs.shape[2:])
        B = h.shape[0]
        cache = {"k": ks, "v": vs,
                 "pos": jnp.full((B,), S_total, dtype=jnp.int32)}
        return logits, aux, cache
    return logits, aux


def init_cache(cfg: ArchConfig, batch: int, context: int, *, dtype=None):
    """Stacked per-layer KV cache [L, B, C, kv, hd] + global pos [B].
    k and v must be DISTINCT buffers — the serve step donates the cache and
    aliased leaves would be donated twice."""
    dt = dtype or cfg.param_dtype
    shape = (cfg.n_layers, batch, context, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype=dt), "v": jnp.zeros(shape, dtype=dt),
            "pos": jnp.zeros((batch,), dtype=jnp.int32)}


def decode_step(params, batch, cache, cfg: ArchConfig, *, ring: bool = False):
    """One-token decode. batch["tokens"] [B, 1]. Returns (logits, new_cache)."""
    h = nn.embedding(params["embed"], batch["tokens"])
    pos = cache["pos"]
    _, norm = _norm_fns(cfg)
    kblk = _block_k(cfg)

    # 100B+ MoE decode keeps expert weights resident (E x d_ff two-axis
    # sharded) and psums activations — re-gathering the weights per token
    # step measured at 1.9 s/step of ICI time
    ep2d = cfg.n_experts > 0 and cfg.param_count() > 1e11

    def one_layer(lp, hh, ck, cv, use_moe: bool):
        layer_cache = {"k": ck, "v": cv, "pos": pos}
        a, new_c = nn.attention_decode(
            lp["attn"], norm(lp["ln_attn"], hh), layer_cache,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, ring=ring, use_flash=cfg.use_flash)
        hh = hh + a
        if use_moe:
            m, _ = nn.moe(lp["moe"], norm(lp["ln_mlp"], hh), top_k=cfg.top_k,
                          ep2d=ep2d)
        else:
            m = nn.mlp(lp["mlp"], norm(lp["ln_mlp"], hh), kind=cfg.mlp_kind)
        return hh + m, new_c

    def body(carry, xs):
        hh = carry
        lp, ck, cv = xs
        if kblk == 1:
            hh, new_c = one_layer(lp, hh, ck, cv, cfg.n_experts > 0)
            return hh, (new_c["k"], new_c["v"])
        nks, nvs = [], []
        for i in range(kblk):       # ck/cv [kblk, B, C, kv, hd]
            hh, new_c = one_layer(lp[f"sub{i}"], hh, ck[i], cv[i],
                                  use_moe=(i == kblk - 1))
            nks.append(new_c["k"])
            nvs.append(new_c["v"])
        return hh, (jnp.stack(nks), jnp.stack(nvs))

    ck, cv = cache["k"], cache["v"]
    if kblk > 1:                    # [L, ...] -> [n_blocks, kblk, ...]
        ck = ck.reshape(cfg.n_layers // kblk, kblk, *ck.shape[1:])
        cv = cv.reshape(cfg.n_layers // kblk, kblk, *cv.shape[1:])
    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], ck, cv))
    if kblk > 1:
        ks = ks.reshape(cfg.n_layers, *ks.shape[2:])
        vs = vs.reshape(cfg.n_layers, *vs.shape[2:])
    h = norm(params["ln_f"], h)
    logits = nn.linear(params["lm_head"], h)
    return logits, {"k": ks, "v": vs, "pos": pos + 1}
