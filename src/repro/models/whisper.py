"""Whisper-style decoder (audio family). The mel/conv encoder frontend is a
STUB — input_specs() supplies encoder frame embeddings [B, enc_len, d]; this
module implements the decoder backbone (self-attn + cross-attn + GELU MLP,
learned positions, pre-LayerNorm).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import nn
from repro.models.config import ArchConfig

MAX_POSITIONS = 4096  # learned table; whisper itself uses 448 target positions


def init_layer(key, cfg: ArchConfig):
    dt = cfg.param_dtype
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln_self": nn.init_layernorm(cfg.d_model, dtype=dt),
        "self_attn": nn.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                       cfg.head_dim, dtype=dt, qkv_bias=True),
        "ln_cross": nn.init_layernorm(cfg.d_model, dtype=dt),
        "cross_attn": nn.init_attention(k2, cfg.d_model, cfg.n_heads, cfg.n_heads,
                                        cfg.head_dim, dtype=dt, qkv_bias=True),
        "ln_mlp": nn.init_layernorm(cfg.d_model, dtype=dt),
        "mlp": nn.init_mlp(k3, cfg.d_model, cfg.d_ff, kind="gelu", dtype=dt),
    }


def init_model(key, cfg: ArchConfig):
    dt = cfg.param_dtype
    k_emb, k_pos, k_layers, k_head = jax.random.split(key, 4)
    return {
        "embed": nn.init_embedding(k_emb, cfg.vocab, cfg.d_model, dtype=dt),
        "pos": nn.init_embedding(k_pos, MAX_POSITIONS, cfg.d_model, dtype=dt),
        "layers": jax.vmap(partial(init_layer, cfg=cfg))(jax.random.split(k_layers, cfg.n_layers)),
        "ln_f": nn.init_layernorm(cfg.d_model, dtype=dt),
        "lm_head": nn.init_linear(k_head, cfg.d_model, cfg.vocab, dtype=dt),
    }


def _cross_kv(lp, enc, cfg: ArchConfig):
    B, T, _ = enc.shape
    k = nn.linear(lp["cross_attn"]["wk"], enc).reshape(B, T, cfg.n_heads, cfg.head_dim)
    v = nn.linear(lp["cross_attn"]["wv"], enc).reshape(B, T, cfg.n_heads, cfg.head_dim)
    return k, v


def _cross_apply(lp, x, ck, cv, cfg: ArchConfig):
    B, S, _ = x.shape
    q = nn.linear(lp["cross_attn"]["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
    from repro.nn.attention import _sdpa
    mask = jnp.ones((1, 1, 1, S, ck.shape[1]), dtype=bool)
    out = _sdpa(q, ck, cv, mask).reshape(B, S, cfg.n_heads * cfg.head_dim)
    return nn.linear(lp["cross_attn"]["wo"], out)


def forward(params, batch, cfg: ArchConfig, *, window=None, shard_h=None,
            last_only: bool = False, return_hidden: bool = False):
    """Teacher-forced decode over a full target sequence. batch: tokens [B,S],
    enc_states [B, enc_len, d]."""
    tokens = batch["tokens"]
    enc = batch["enc_states"].astype(cfg.param_dtype)
    B, S = tokens.shape
    pos_ids = jnp.arange(S, dtype=jnp.int32) % MAX_POSITIONS
    h = nn.embedding(params["embed"], tokens) + nn.embedding(params["pos"], pos_ids)[None]

    def body(carry, lp):
        hh = carry
        if shard_h is not None:
            hh = shard_h(hh)
        a, _ = nn.attention_prefill(
            lp["self_attn"], nn.layernorm(lp["ln_self"], hh),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            rope_theta=None, window=window, use_flash=cfg.use_flash)
        hh = hh + a
        ck, cv = _cross_kv(lp, enc, cfg)
        hh = hh + _cross_apply(lp, nn.layernorm(lp["ln_cross"], hh), ck, cv, cfg)
        hh = hh + nn.mlp(lp["mlp"], nn.layernorm(lp["ln_mlp"], hh), kind="gelu")
        if shard_h is not None:
            hh = shard_h(hh)
        return hh, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    if last_only:
        h = h[:, -1:]
    h = nn.layernorm(params["ln_f"], h)
    aux = {"lb_loss": jnp.zeros((), jnp.float32), "dropped_frac": jnp.zeros((), jnp.float32)}
    if return_hidden:          # train fuses lm_head into the chunked loss
        return h, aux
    logits = nn.linear(params["lm_head"], h)
    return logits, aux


def init_cache(cfg: ArchConfig, batch: int, context: int, *, dtype=None):
    dt = dtype or cfg.param_dtype
    # distinct buffers per leaf — the serve step donates the cache and
    # aliased leaves would be donated twice
    sh = (cfg.n_layers, batch, context, cfg.n_kv, cfg.head_dim)
    shc = (cfg.n_layers, batch, cfg.enc_len, cfg.n_heads, cfg.head_dim)
    return {"k": jnp.zeros(sh, dtype=dt), "v": jnp.zeros(sh, dtype=dt),
            "ck": jnp.zeros(shc, dtype=dt), "cv": jnp.zeros(shc, dtype=dt),
            "pos": jnp.zeros((batch,), dtype=jnp.int32)}


def prefill_cache(params, batch, cfg: ArchConfig, context: int):
    """Populate the cross-attention KV from encoder states (done once)."""
    enc = batch["enc_states"].astype(cfg.param_dtype)

    def per_layer(lp):
        return _cross_kv(lp, enc, cfg)

    ck, cv = jax.vmap(per_layer)(params["layers"])
    cache = init_cache(cfg, enc.shape[0], context)
    return {**cache, "ck": ck, "cv": cv}


def decode_step(params, batch, cache, cfg: ArchConfig, *, ring: bool = False):
    tokens = batch["tokens"]
    pos = cache["pos"]
    pos_ids = (pos % MAX_POSITIONS)[:, None]
    h = nn.embedding(params["embed"], tokens) + nn.embedding(params["pos"], pos_ids)

    def body(carry, xs):
        hh = carry
        lp, ck_self, cv_self, ck_x, cv_x = xs
        layer_cache = {"k": ck_self, "v": cv_self, "pos": pos}
        a, new_c = nn.attention_decode(
            lp["self_attn"], nn.layernorm(lp["ln_self"], hh), layer_cache,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            rope_theta=None, ring=ring, use_flash=cfg.use_flash)
        hh = hh + a
        hh = hh + _cross_apply(lp, nn.layernorm(lp["ln_cross"], hh), ck_x, cv_x, cfg)
        hh = hh + nn.mlp(lp["mlp"], nn.layernorm(lp["ln_mlp"], hh), kind="gelu")
        return hh, (new_c["k"], new_c["v"])

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["layers"], cache["k"], cache["v"], cache["ck"], cache["cv"]))
    h = nn.layernorm(params["ln_f"], h)
    logits = nn.linear(params["lm_head"], h)
    return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"], "pos": pos + 1}
