"""jax version bridging. The code targets the current mesh surface
(``jax.shard_map``, ``jax.sharding.get_abstract_mesh``, typed ``make_mesh``);
the baked toolchain may carry an older jax where those live elsewhere. Every
mesh-aware call site imports from here so version drift stays in one file.
Imports only jax — safe from any module without cycles.
"""
from __future__ import annotations

import jax

try:                                      # jax >= 0.5
    from jax import shard_map             # type: ignore[attr-defined]
except ImportError:                       # older: experimental namespace
    from jax.experimental.shard_map import shard_map  # noqa: F401


def ambient_mesh():
    """The mesh currently in scope, or None.

    New jax: the AbstractMesh set by ``jax.sharding.use_mesh``. Old jax: the
    physical mesh entered via ``with mesh:`` (thread-resources env).
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        mesh = get()
        return mesh if mesh is not None and mesh.axis_names else None
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def abstract_mesh(shape, axes):
    """AbstractMesh across constructor generations: new jax takes
    (axis_sizes, axis_names); old jax takes ((name, size), ...) pairs."""
    AbstractMesh = jax.sharding.AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape, strict=True)))


def use_mesh(mesh):
    """Context manager making ``mesh`` ambient: ``jax.set_mesh`` /
    ``jax.sharding.use_mesh`` where present, the mesh's own context manager
    (``with mesh:``) on older jax."""
    setter = getattr(jax, "set_mesh", None) \
        or getattr(jax.sharding, "use_mesh", None)
    return setter(mesh) if setter is not None else mesh


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict: older jax returns a per-device
    list of dicts, newer jax the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def pallas_tpu_compiler_params(**kwargs):
    """Pallas TPU compiler params: ``CompilerParams`` on new jax,
    ``TPUCompilerParams`` on older releases."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def make_mesh(shape, axes):
    """Typed mesh when AxisType exists (auto sharding axes), plain otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
