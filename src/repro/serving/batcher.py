"""Request batching: each pipeline stage has a centralized queue (paper
§III-A) and a batcher that groups pending requests up to the configured
batch size, padding the tail batch."""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # [S] int32 prompt for the first stage
    arrival: float = 0.0
    result: np.ndarray | None = None
    stage_outputs: list = field(default_factory=list)


class Batcher:
    def __init__(self, batch_size: int, seq_len: int):
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.queue: deque[Request] = deque()

    def put(self, req: Request):
        self.queue.append(req)

    def __len__(self) -> int:
        return len(self.queue)

    def next_batch(self) -> tuple[list[Request], np.ndarray] | None:
        """Pop up to batch_size requests -> (requests, tokens [B, S]).
        The tail batch is padded by repeating the last request's tokens."""
        if not self.queue:
            return None
        reqs = [self.queue.popleft()
                for _ in range(min(self.batch_size, len(self.queue)))]
        toks = np.zeros((self.batch_size, self.seq_len), dtype=np.int32)
        for i in range(self.batch_size):
            src = reqs[min(i, len(reqs) - 1)].tokens[:self.seq_len]
            toks[i, :len(src)] = src
        return reqs, toks
