"""Request batching: each pipeline stage has a centralized queue (paper
§III-A).

Two batchers:

- ``Batcher`` — the simple drain-the-queue batcher used by the blocking
  ``PipelineServer`` path. It dispatches the *actual* number of pending
  requests (up to ``batch_size``); no tail padding — padded rows used to
  repeat the last request's tokens and waste a full batch of compute on
  mostly-duplicate work.
- ``ContinuousBatcher`` — the event-driven runtime's batcher: requests are
  timestamped on enqueue and a batch dispatches when it is *full* or when the
  oldest request has waited ``max_wait`` virtual seconds (timeout-or-full,
  the InferLine/clipper-style continuous batching discipline).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # [S] int32 prompt for the first stage
    arrival: float = 0.0               # virtual arrival time (s)
    finish: float | None = None        # virtual completion time (s)
    result: np.ndarray | None = None
    stage_outputs: list = field(default_factory=list)

    @property
    def latency(self) -> float | None:
        """End-to-end virtual latency, once served."""
        return None if self.finish is None else self.finish - self.arrival


def stack_tokens(reqs: list[Request], seq_len: int) -> np.ndarray:
    """Stack request prompts -> tokens [len(reqs), seq_len], zero-padding
    (or truncating) each sequence to ``seq_len``. The batch dimension is the
    actual number of requests — callers never pay for phantom rows."""
    toks = np.zeros((len(reqs), seq_len), dtype=np.int32)
    for i, req in enumerate(reqs):
        src = req.tokens[:seq_len]
        toks[i, :len(src)] = src
    return toks


class Batcher:
    def __init__(self, batch_size: int, seq_len: int):
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.queue: deque[Request] = deque()

    def put(self, req: Request):
        self.queue.append(req)

    def __len__(self) -> int:
        return len(self.queue)

    def next_batch(self) -> tuple[list[Request], np.ndarray] | None:
        """Pop up to batch_size requests -> (requests, tokens [B_actual, S])."""
        if not self.queue:
            return None
        reqs = [self.queue.popleft()
                for _ in range(min(self.batch_size, len(self.queue)))]
        return reqs, stack_tokens(reqs, self.seq_len)


class ContinuousBatcher:
    """Timeout-or-full batching against a virtual clock.

    ``ready(now)`` is True when a batch should dispatch; ``deadline()`` is
    the virtual time at which the oldest pending request times out (for the
    event loop to schedule a timer).
    """

    def __init__(self, batch_size: int, *, max_wait: float = 0.05):
        self.batch_size = int(batch_size)
        self.max_wait = float(max_wait)
        self.queue: deque[tuple[Request, float]] = deque()

    def put(self, req: Request, now: float):
        self.queue.append((req, now))

    def __len__(self) -> int:
        return len(self.queue)

    def deadline(self) -> float | None:
        """Virtual time when the oldest request's wait hits ``max_wait``."""
        if not self.queue:
            return None
        return self.queue[0][1] + self.max_wait

    def ready(self, now: float) -> bool:
        if not self.queue:
            return False
        return (len(self.queue) >= self.batch_size
                or now >= self.deadline() - 1e-12)

    def pop(self, now: float) -> list[Request]:
        """Dispatch up to ``batch_size`` requests (actual count, no padding)."""
        n = min(self.batch_size, len(self.queue))
        return [self.queue.popleft()[0] for _ in range(n)]
