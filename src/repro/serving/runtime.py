"""Event-driven, clock-stepped serving runtime.

A virtual-time event loop drives requests from pluggable arrival processes
(`serving.arrivals`) through per-stage continuous batchers (timeout-or-full
dispatch, actual batch sizes — no tail padding) and replica pools. Per-batch
service times are charged from the analytic perf model (each stage's
`core.mdp.ModelVariant` latency curve, built by `cluster.perf_model`), with
optional real JAX execution through a stage ``executor`` (e.g.
`serving.engine.StageServer.execute`) so outputs flow through live models
while virtual time stays deterministic.

The OPD control loop closes over this runtime: ``apply_config`` is the live
reconfiguration (paper: Kubernetes API) — a variant switch blocks the stage
for ``COLD_START_SECONDS`` of virtual time (container re-pull / weight
re-shard), replica and batch knobs take effect immediately. The
`cluster.env.RuntimeEnv` adapter exposes the same MDP interface the analytic
simulator does, scored from measured telemetry.

Event ordering is deterministic: ties in virtual time break by insertion
sequence (FIFO), so identical seeds reproduce identical schedules.

The event heap itself lives in an :class:`EventLoop` that a runtime either
owns privately (the classic single-pipeline case) or shares with other
runtimes — a multi-tenant fleet (`serving.fleet`) hosts N pipelines on one
loop, interleaving their events in one deterministic virtual timeline.
"""
from __future__ import annotations

import heapq
import itertools

import numpy as np

from repro.core.mdp import Config, Pipeline, Task, placement_for
from repro.serving.batcher import ContinuousBatcher, Request, stack_tokens
from repro.serving.telemetry import Telemetry

# Virtual-time cost of a variant switch: the paper's cold start loses
# COLD_START_FRACTION (0.3) of a 10 s adaptation interval's capacity.
COLD_START_SECONDS = 3.0
DEFAULT_MAX_WAIT = 0.25   # s a request may wait before a partial batch fires


class EventLoop:
    """A virtual-time event heap shared by one or more runtimes.

    Each pushed event carries its owning runtime; ``run_until`` pops events
    in (time, insertion-sequence) order and routes them back to the owner's
    ``_handle``. The insertion sequence is global across owners, so a fleet
    of runtimes sharing one loop interleaves deterministically — and a loop
    with a single owner behaves exactly like the historical private heap.
    """

    def __init__(self):
        self.now = 0.0
        self.events = 0               # total events processed (fleet events/s)
        self._heap: list[tuple] = []
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, t: float, owner, kind: str, payload):
        # owner sits *after* payload: seq is unique, so comparisons never
        # reach it (runtimes are not orderable)
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload, owner))

    def next_time(self) -> float | None:
        return self._heap[0][0] if self._heap else None

    def run_until(self, t_end: float):
        """Process all events with time <= t_end; clock lands on t_end."""
        while self._heap and self._heap[0][0] <= t_end + 1e-12:
            t, _, kind, payload, owner = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            self.events += 1
            owner._handle(kind, payload)
        self.now = max(self.now, t_end)

    def drain(self):
        """Run the loop dry — every admitted request completes."""
        while self._heap:
            self.run_until(self._heap[0][0])


class RuntimeStage:
    """One pipeline stage: variant timing models, a continuous batcher and a
    replica pool. ``executor(z, tokens[B, S]) -> outputs [B, S]`` optionally
    runs a real model; otherwise stage output = input tokens.

    Replicas live on cluster nodes (``replica_nodes`` / ``replica_speeds``
    from the placement scheduler): a dispatch claims the fastest free
    replica, whose node speed scales the batch's service time and whose node
    is charged the replica-seconds."""

    def __init__(self, name: str, task: Task, *, z: int = 0, replicas: int = 1,
                 batch_size: int = 1, max_wait: float = DEFAULT_MAX_WAIT,
                 seq_len: int = 32, executor=None):
        self.name = name
        self.task = task
        self.z = int(z) % len(task.variants)
        self.replicas = max(1, int(replicas))
        self.replica_nodes: tuple[int, ...] = (0,) * self.replicas
        self.replica_speeds: tuple[float, ...] = (1.0,) * self.replicas
        self.batcher = ContinuousBatcher(batch_size, max_wait=max_wait)
        self.seq_len = seq_len
        self.executor = executor
        self.in_flight = 0
        self._busy: set[int] = set()  # replica indices currently serving
        self.blocked_until = 0.0      # cold-start gate (virtual s)
        self.warm_z: int | None = None  # variant being pre-warmed off-path
        self.warm_ready = 0.0           # virtual time its cold start finishes
        self.busy_time = 0.0          # Σ replica-seconds of service charged
        self.served = 0
        self._pending_timer: float | None = None
        # replica-seconds integral (replicas change across reconfigs)
        self._cap_accum = 0.0
        self._cap_since = 0.0

    @property
    def var(self):
        return self.task.variants[self.z]

    def service_time(self, batch: int, speed: float = 1.0) -> float:
        return self.var.latency(batch) / speed

    def claim_replica(self) -> int:
        """The fastest free replica index (ties -> lowest index). Callers
        must hold ``in_flight < replicas``, which guarantees a free one."""
        free = [r for r in range(self.replicas) if r not in self._busy]
        idx = max(free, key=lambda r: (self.replica_speeds[r], -r))
        self._busy.add(idx)
        return idx

    def release_replica(self, idx: int):
        self._busy.discard(idx)

    def set_replicas(self, replicas: int, now: float,
                     nodes: tuple[int, ...] | None = None,
                     speeds: tuple[float, ...] | None = None):
        self._cap_accum += (now - self._cap_since) * self.replicas
        self._cap_since = now
        self.replicas = max(1, int(replicas))
        self.replica_nodes = (tuple(nodes) if nodes is not None
                              else (0,) * self.replicas)
        self.replica_speeds = (tuple(speeds) if speeds is not None
                               else (1.0,) * self.replicas)

    def replica_seconds(self, now: float) -> float:
        return self._cap_accum + (now - self._cap_since) * self.replicas


class ServingRuntime:
    def __init__(self, stages: list[RuntimeStage], *,
                 telemetry: Telemetry | None = None, pipe: Pipeline | None = None,
                 loop: EventLoop | None = None):
        self.stages = stages
        self.telemetry = telemetry or Telemetry()
        self._loop = loop if loop is not None else EventLoop()
        self.completed: list[Request] = []
        self.in_system = 0            # arrived, not yet fully served
        self.switch_count = 0
        self.prewarm_count = 0        # off-path variant warm-ups started
        self.migration_count = 0      # replicas moved across nodes by reconfigs
        self.last_migrations = 0
        self.stale_timers_dropped = 0  # superseded timer events ignored
        # admission hook (multi-tenant load shedding): ``admission(runtime,
        # request) -> bool`` decides at arrival time; a rejected request is
        # recorded as offered + shed and never enters a queue
        self.admission = None
        # cluster topology: placement charges replica-seconds per node and
        # adjacent stages on different primary nodes pay a transfer hop
        self.pipe = pipe
        self.topo = pipe.topo if pipe is not None else None
        n_nodes = self.topo.n_nodes if self.topo is not None else 1
        self.node_busy = [0.0] * n_nodes
        self._node_repl = [0] * n_nodes
        self._node_accum = [0.0] * n_nodes
        self._node_since = 0.0
        self._primary = tuple(0 for _ in stages)
        if pipe is not None:
            self._install_placement(placement_for(pipe, self.config))

    @property
    def now(self) -> float:
        """The virtual clock — owned by the (possibly shared) event loop."""
        return self._loop.now

    # ----------------------------------------------------------- set-up --

    @classmethod
    def from_pipeline(cls, pipe: Pipeline, *, cfg: Config | None = None,
                      max_wait: float = DEFAULT_MAX_WAIT, seq_len: int = 32,
                      executors: list | None = None,
                      loop: EventLoop | None = None) -> ServingRuntime:
        """Stages mirror ``pipe``'s tasks; initial knobs from ``cfg``
        (default: cheapest variant, 1 replica, batch 1). Replicas are placed
        on ``pipe``'s cluster topology by the shared first-fit scheduler.
        ``loop`` shares an event loop with other runtimes (fleet serving)."""
        if cfg is None:
            n = pipe.n_tasks
            cfg = Config(z=(0,) * n, f=(1,) * n, b=(1,) * n)
        stages = [
            RuntimeStage(task.name, task, z=cfg.z[i], replicas=cfg.f[i],
                         batch_size=cfg.b[i], max_wait=max_wait,
                         seq_len=seq_len,
                         executor=executors[i] if executors else None)
            for i, task in enumerate(pipe.tasks)
        ]
        return cls(stages, pipe=pipe, loop=loop)

    def _install_placement(self, pl):
        """Point every stage's replica pool at its assigned nodes and roll
        the per-node replica-seconds integral forward."""
        speeds = [n.speed for n in self.topo.nodes]
        for k in range(len(self._node_repl)):
            self._node_accum[k] += ((self.now - self._node_since)
                                    * self._node_repl[k])
        self._node_since = self.now
        counts = [0] * len(self._node_repl)
        for stage, nodes in zip(self.stages, pl.nodes, strict=True):
            stage.replica_nodes = tuple(nodes)
            stage.replica_speeds = tuple(speeds[k] for k in nodes)
            for k in nodes:
                counts[k] += 1
        self._node_repl = counts
        self._primary = pl.primary

    def load(self, process, horizon: float, *, vocab: int = 256,
             seq_len: int | None = None, rid_base: int = 0) -> int:
        """Pre-register arrivals from ``process`` over [now, now+horizon)."""
        seq_len = seq_len or self.stages[0].seq_len
        times = process.generate(horizon) + self.now
        rng = np.random.default_rng(process.seed + 1)
        for i, t in enumerate(times):
            toks = rng.integers(1, vocab, size=seq_len).astype(np.int32)
            self.submit(Request(rid=rid_base + i, tokens=toks), at=float(t))
        return len(times)

    def submit(self, req: Request, *, at: float | None = None):
        t = self.now if at is None else at
        req.arrival = t
        self._push(t, "arrival", req)

    # ------------------------------------------------------ control API --

    def prewarm(self, stage: int, z: int, *,
                cold_start: float = COLD_START_SECONDS) -> bool:
        """Start warming variant ``z`` on ``stage`` *off the serving path*:
        the cold start runs in the background (container pull / weight load
        on spare node capacity) while the live variant keeps serving. A
        later ``apply_config`` switching this stage to ``z`` pays only the
        warm-up still outstanding — zero if ``cold_start`` seconds have
        already elapsed. A no-op when ``z`` is already live or already
        warming; re-warming a *different* variant replaces the previous
        warm (one standby slot per stage). Returns True iff a warm-up was
        started."""
        st = self.stages[stage]
        z = int(z) % len(st.task.variants)
        if z == st.z:
            return False
        if st.warm_z == z:
            return False  # already warming (possibly already ready)
        st.warm_z = z
        st.warm_ready = self.now + cold_start
        self.prewarm_count += 1
        return True

    def apply_config(self, cfg: Config, *,
                     cold_start: float = COLD_START_SECONDS) -> int:
        """Live reconfiguration (the OPD action). Variant switches pay
        ``cold_start`` virtual seconds of stage unavailability; queued
        requests hold (nothing is dropped). Replicas are re-placed on the
        cluster by the shared scheduler; ``last_migrations`` reports how many
        continuing replicas had to move nodes. Returns #stages switched."""
        switched = 0
        pl = None
        if self.pipe is not None:
            old_nodes = [s.replica_nodes for s in self.stages]
            pl = placement_for(self.pipe, cfg)
        for n, stage in enumerate(self.stages):
            z_new = int(cfg.z[n]) % len(stage.task.variants)
            if z_new != stage.z:
                switched += 1
                stage.z = z_new
                if stage.warm_z == z_new:
                    # pre-warmed: pay only the warm-up still outstanding
                    # (zero once warm_ready has passed)
                    stage.blocked_until = max(stage.blocked_until,
                                              stage.warm_ready)
                else:
                    stage.blocked_until = max(stage.blocked_until,
                                              self.now + cold_start)
                # any variant switch retires the standby slot: a warm for
                # the new variant is consumed, a warm for some other
                # variant is stale (the fabric re-targets the slot)
                stage.warm_z = None
            stage.set_replicas(int(cfg.f[n]), self.now)
            stage.batcher.batch_size = max(1, int(cfg.b[n]))
        if pl is not None:
            self._install_placement(pl)
            self.last_migrations = sum(
                _migrations(old, stage.replica_nodes)
                for old, stage in zip(old_nodes, self.stages, strict=True))
            self.migration_count += self.last_migrations
        self.switch_count += switched
        self.telemetry.record_reconfig(self.now, switched)
        for i, stage in enumerate(self.stages):
            # timers armed under the old configuration (old batch deadline /
            # cold-start gate, possibly retired batchers or replicas) are no
            # longer authoritative: invalidate them so the poke below arms a
            # fresh one for the *new* configuration and the heaped ones are
            # dropped as stale when they fire
            stage._pending_timer = None
            self._poke(i)
        return switched

    @property
    def config(self) -> Config:
        return Config(z=tuple(s.z for s in self.stages),
                      f=tuple(s.replicas for s in self.stages),
                      b=tuple(s.batcher.batch_size for s in self.stages))

    # -------------------------------------------------------- event loop --

    def _push(self, t: float, kind: str, payload):
        self._loop.push(t, self, kind, payload)

    def run_until(self, t_end: float):
        """Process all events with time <= t_end; clock lands on t_end.
        On a shared loop this advances *every* runtime on it — the fleet's
        tenants march through one interleaved virtual timeline."""
        self._loop.run_until(t_end)

    def drain(self):
        """Run the loop dry — every admitted request completes."""
        self._loop.drain()

    # ---------------------------------------------------------- handlers --

    def _handle(self, kind: str, payload):
        """Event dispatch — called by the (possibly shared) event loop."""
        if kind == "arrival":
            self._on_arrival(payload)
        elif kind == "complete":
            self._on_complete(*payload)
        elif kind == "timer":
            self._on_timer(*payload)
        elif kind == "xfer":
            self._on_xfer(*payload)

    def _on_arrival(self, req: Request):
        self.telemetry.record_arrival(self.now)
        if self.admission is not None and not self.admission(self, req):
            # shed: counted as offered load, never queued, never completes
            self.telemetry.record_shed(self.now)
            return
        self.in_system += 1
        self.stages[0].batcher.put(req, self.now)
        self._poke(0)

    def _on_timer(self, i: int, armed_at: float):
        """A timer is only actionable if it is still the stage's pending one.
        Reconfigurations (and re-arms at a different deadline) supersede
        previously heaped timers — those must be ignored, not fired against
        the new configuration."""
        stage = self.stages[i]
        if (stage._pending_timer is None
                or abs(stage._pending_timer - armed_at) > 1e-12):
            self.stale_timers_dropped += 1
            return
        stage._pending_timer = None
        self._poke(i)

    def _on_complete(self, i: int, reqs: list[Request], z: int,
                     replica: int = 0):
        stage = self.stages[i]
        stage.in_flight -= 1
        stage.release_replica(replica)
        stage.served += len(reqs)
        if stage.executor is not None:
            out = np.asarray(stage.executor(
                z, stack_tokens(reqs, stage.seq_len)))
            for k, req in enumerate(reqs):
                req.stage_outputs.append(out[k])
                req.result = out[k]
        else:
            for req in reqs:
                req.stage_outputs.append(req.tokens)
                req.result = req.tokens
        if i + 1 < len(self.stages):
            for req in reqs:
                # next stage consumes this stage's output tokens
                req.tokens = np.asarray(req.result, dtype=np.int32).reshape(-1)
            hop = self.topo.hop_latency if self.topo is not None else 0.0
            if hop > 0.0 and self._primary[i] != self._primary[i + 1]:
                # cross-node transfer: the batch reaches the next stage's
                # queue only after the network hop
                self._push(self.now + hop, "xfer", (i + 1, reqs))
            else:
                self._on_xfer(i + 1, reqs)
        else:
            for req in reqs:
                req.finish = self.now
                self.telemetry.record_completion(req.rid, req.arrival, self.now)
                self.completed.append(req)
            self.in_system -= len(reqs)
        self._poke(i)

    def _on_xfer(self, i: int, reqs: list[Request]):
        nxt = self.stages[i]
        for req in reqs:
            nxt.batcher.put(req, self.now)
        self._poke(i)

    def _poke(self, i: int):
        """Dispatch every batch the stage can take now; otherwise arm a timer
        for the next timeout-or-unblock instant."""
        stage = self.stages[i]
        while (stage.in_flight < stage.replicas
               and self.now >= stage.blocked_until - 1e-12
               and stage.batcher.ready(self.now)):
            reqs = stage.batcher.pop(self.now)
            replica = stage.claim_replica()
            service = stage.service_time(len(reqs),
                                         stage.replica_speeds[replica])
            stage.in_flight += 1
            stage.busy_time += service
            node = stage.replica_nodes[replica]
            if node < len(self.node_busy):
                self.node_busy[node] += service
            self.telemetry.record_batch(i, self.now, len(reqs), service,
                                        len(stage.batcher))
            # pin the dispatch-time variant and replica: a mid-flight switch
            # must not change which model serves an already-running batch
            self._push(self.now + service, "complete",
                       (i, reqs, stage.z, replica))
        if len(stage.batcher) and stage.in_flight < stage.replicas:
            t_need = max(stage.batcher.deadline(), stage.blocked_until)
            live = (stage._pending_timer is not None
                    and self.now - 1e-12 <= stage._pending_timer <= t_need + 1e-12)
            if t_need > self.now and not live:
                self._push(t_need, "timer", (i, t_need))
                stage._pending_timer = t_need

    # ----------------------------------------------------------- queries --

    def queue_depths(self) -> list[int]:
        return [len(s.batcher) for s in self.stages]

    def utilization(self) -> list[float]:
        return [s.busy_time / max(s.replica_seconds(self.now), 1e-9)
                for s in self.stages]

    def node_replica_seconds(self) -> list[float]:
        return [acc + (self.now - self._node_since) * n
                for acc, n in zip(self._node_accum, self._node_repl,
                                  strict=True)]

    def node_utilization(self) -> list[float]:
        """Per-node busy replica-seconds over available replica-seconds."""
        return [busy / max(cap, 1e-9)
                for busy, cap in zip(self.node_busy,
                                     self.node_replica_seconds(),
                                     strict=True)]

    def summary(self) -> dict:
        out = self.telemetry.summary(
            self.now,
            stage_busy=[s.busy_time for s in self.stages],
            stage_capacity=[s.replica_seconds(self.now)
                            for s in self.stages])
        out["migrations"] = self.migration_count
        out["prewarms"] = self.prewarm_count
        if self.topo is not None and self.topo.n_nodes > 1:
            out["node_busy_s"] = list(self.node_busy)
            out["node_utilization"] = self.node_utilization()
        return out


def _migrations(old: tuple[int, ...], new: tuple[int, ...]) -> int:
    """Continuing replicas of a stage that had to move nodes: the overlap
    shortfall between the old and new node multisets."""
    overlap = 0
    nodes = set(old) | set(new)
    for k in nodes:
        overlap += min(old.count(k), new.count(k))
    return max(0, min(len(old), len(new)) - overlap)
