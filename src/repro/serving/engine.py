"""Pipeline serving engine: real JAX models behind each stage.

StageServer = one task's deployment: a model variant (ArchConfig), a batch
size, and a replica count (replicas are data-parallel splits of a batch; on
the CPU dev box they execute sequentially but the abstraction mirrors the
mesh "data"-axis replica groups of the production launch).

PipelineServer chains stages (the paper's gRPC hops) and implements
``apply_config`` — the Kubernetes-API reconfiguration the OPD agent calls:
switching a stage's variant swaps model params (a re-shard/cold-start in
production, charged by the simulator).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mdp import Config
from repro.models import api
from repro.models.config import ArchConfig
from repro.serving.batcher import Batcher, Request


class StageServer:
    def __init__(self, name: str, variants: list[ArchConfig], *,
                 seq_len: int = 32, batch_size: int = 4, replicas: int = 1,
                 seed: int = 0):
        self.name = name
        self.variants = variants
        self.seq_len = seq_len
        self.params = [api.init_model(jax.random.PRNGKey(seed + i), cfg)
                       for i, cfg in enumerate(variants)]
        self.z = 0
        self.replicas = replicas
        self.batcher = Batcher(batch_size, seq_len)
        self._fwd_cache: dict[int, callable] = {}
        self.served = 0

    @property
    def cfg(self) -> ArchConfig:
        return self.variants[self.z]

    def _fwd(self, z: int):
        if z not in self._fwd_cache:
            cfg = self.variants[z]

            @jax.jit
            def fwd(params, batch):
                logits, _ = api.forward(params, batch, cfg)
                return jnp.argmax(logits, axis=-1)

            self._fwd_cache[z] = fwd
        return self._fwd_cache[z]

    def configure(self, *, z: int | None = None, batch_size: int | None = None,
                  replicas: int | None = None):
        if z is not None:
            self.z = int(z) % len(self.variants)
        if batch_size is not None:
            self.batcher.batch_size = int(batch_size)
        if replicas is not None:
            self.replicas = int(replicas)

    def _make_batch(self, tokens: np.ndarray, cfg: ArchConfig) -> dict:
        batch = {"tokens": jnp.asarray(tokens % cfg.vocab)}
        B = tokens.shape[0]
        if cfg.family == "vlm":
            key = jax.random.PRNGKey(0)
            batch["vision_embeds"] = jax.random.normal(
                key, (B, cfg.n_patches, cfg.d_model), jnp.float32) * 0.02
        if cfg.family == "audio":
            key = jax.random.PRNGKey(1)
            batch["enc_states"] = jax.random.normal(
                key, (B, cfg.enc_len, cfg.d_model), jnp.float32) * 0.02
        return batch

    def execute(self, z: int, tokens: np.ndarray) -> np.ndarray:
        """Run variant ``z`` on tokens [B, S] -> output tokens [B, S].

        This is the real-JAX execution hook: the event-driven runtime
        (serving.runtime) can attach it as a stage ``executor`` so virtual
        time is charged analytically while outputs flow through live models.
        Batches arrive at their actual size (no tail padding) — jit retraces
        per distinct (z, B) shape and then reuses the compiled kernel.
        """
        z = int(z) % len(self.variants)
        fwd = self._fwd(z)
        return np.asarray(fwd(self.params[z],
                              self._make_batch(tokens, self.variants[z])))

    def serve_pending(self) -> list[Request]:
        """Drain the queue; returns completed requests with stage output."""
        done = []
        while True:
            nb = self.batcher.next_batch()
            if nb is None:
                return done
            reqs, toks = nb
            # replicas split the batch (data parallel); sequential on CPU
            out = self.execute(self.z, toks)
            for i, req in enumerate(reqs):
                req.stage_outputs.append(out[i])
                req.result = out[i]
                done.append(req)
            self.served += len(reqs)


class PipelineServer:
    def __init__(self, stages: list[StageServer]):
        self.stages = stages
        self.completed: list[Request] = []
        self.switch_count = 0

    def apply_config(self, cfg: Config, batch_choices: list[int] | None = None):
        """The OPD action -> live reconfiguration (paper: K8s Python API)."""
        for n, stage in enumerate(self.stages):
            if stage.z != cfg.z[n] % len(stage.variants):
                self.switch_count += 1
            stage.configure(z=cfg.z[n], batch_size=cfg.b[n], replicas=cfg.f[n])

    def submit(self, req: Request):
        self.stages[0].batcher.put(req)

    def process(self) -> list[Request]:
        """Push every queued request through all stages (gRPC chain)."""
        for i, stage in enumerate(self.stages):
            finished = stage.serve_pending()
            if i + 1 < len(self.stages):
                for req in finished:
                    # next stage consumes this stage's output tokens
                    req.tokens = np.asarray(req.result, dtype=np.int32)
                    self.stages[i + 1].batcher.put(req)
            else:
                self.completed.extend(finished)
        return self.completed
