"""Telemetry store for the serving runtime (the Prometheus of the paper's
§III-A monitoring, but per-request): end-to-end latency records with
p50/p95/p99, per-second arrival counts (the predictor's load history), batch
dispatch log, queue depths and per-stage busy-time utilization.

Interval queries (``completed_in`` / ``arrived_in`` / ``latencies``) are
O(log n + window): the event loop records completions in non-decreasing
finish time and arrivals in non-decreasing arrival time, so both live in
sorted parallel arrays sliced with ``bisect`` (an out-of-order record falls
back to an insort, keeping the invariant). ``benchmarks/telemetry_queries.py``
asserts per-query cost stays flat as the record count grows.
"""
from __future__ import annotations

from bisect import bisect_left, insort
from collections import defaultdict
from dataclasses import dataclass

import numpy as np


def percentile(xs: np.ndarray, p: float) -> float:
    """Linear-interpolated percentile, NaN on empty (np.percentile raises)."""
    xs = np.asarray(xs, dtype=np.float64)
    if xs.size == 0:
        return float("nan")
    return float(np.percentile(xs, p))


@dataclass
class BatchRecord:
    stage: int
    time: float          # dispatch time (virtual s)
    size: int            # actual batch size dispatched
    service: float       # charged service time (virtual s)
    queue_depth: int     # depth left behind after the pop


@dataclass
class CompletionRecord:
    rid: int
    arrival: float
    finish: float

    @property
    def latency(self) -> float:
        return self.finish - self.arrival


class Telemetry:
    def __init__(self):
        self.arrival_counts: dict[int, int] = defaultdict(int)  # second -> n
        self.completions: list[CompletionRecord] = []
        self.batches: list[BatchRecord] = []
        self.reconfigs: list[tuple[float, int]] = []  # (time, n_switched)
        # sorted parallel indexes for O(log n) interval queries
        self._arrival_times: list[float] = []
        self._finish_times: list[float] = []
        self._latencies: list[float] = []
        # arrivals rejected by admission control (multi-tenant overload):
        # shed requests count as *offered* load (they are recorded as
        # arrivals) but never enter a queue and never complete
        self._shed_times: list[float] = []

    # -------------------------------------------------------- recording --

    def record_arrival(self, t: float):
        self.arrival_counts[int(t)] += 1
        if self._arrival_times and t < self._arrival_times[-1]:
            insort(self._arrival_times, t)
        else:
            self._arrival_times.append(t)

    def record_completion(self, rid: int, arrival: float, finish: float):
        self.completions.append(CompletionRecord(rid, arrival, finish))
        if self._finish_times and finish < self._finish_times[-1]:
            i = bisect_left(self._finish_times, finish)
            self._finish_times.insert(i, finish)
            self._latencies.insert(i, finish - arrival)
        else:
            self._finish_times.append(finish)
            self._latencies.append(finish - arrival)

    def record_shed(self, t: float):
        if self._shed_times and t < self._shed_times[-1]:
            insort(self._shed_times, t)
        else:
            self._shed_times.append(t)

    def record_batch(self, stage: int, t: float, size: int, service: float,
                     queue_depth: int):
        self.batches.append(BatchRecord(stage, t, size, service, queue_depth))

    def record_reconfig(self, t: float, n_switched: int):
        self.reconfigs.append((t, n_switched))

    # ---------------------------------------------------------- queries --

    def _finish_window(self, t0: float, t1: float) -> tuple[int, int]:
        return (bisect_left(self._finish_times, t0),
                bisect_left(self._finish_times, t1))

    def latencies(self, t0: float = -np.inf, t1: float = np.inf) -> np.ndarray:
        """End-to-end latencies of requests finishing in [t0, t1)."""
        lo, hi = self._finish_window(t0, t1)
        return np.asarray(self._latencies[lo:hi], dtype=np.float64)

    def completed_in(self, t0: float, t1: float) -> int:
        lo, hi = self._finish_window(t0, t1)
        return hi - lo

    def arrived_in(self, t0: float, t1: float) -> int:
        return (bisect_left(self._arrival_times, t1)
                - bisect_left(self._arrival_times, t0))

    def shed_in(self, t0: float, t1: float) -> int:
        return (bisect_left(self._shed_times, t1)
                - bisect_left(self._shed_times, t0))

    @property
    def shed(self) -> int:
        return len(self._shed_times)

    def load_history(self, now: float, history: int = 120) -> np.ndarray:
        """Per-second arrival counts over the last ``history`` seconds —
        what the LSTM workload predictor consumes."""
        end = int(now)
        return np.asarray([self.arrival_counts.get(s, 0)
                           for s in range(end - history, end)],
                          dtype=np.float64)

    def latency_percentiles(self, ps=(50, 95, 99), *, t0: float = -np.inf,
                            t1: float = np.inf) -> dict[str, float]:
        lat = self.latencies(t0, t1)
        return {f"p{p}": percentile(lat, p) for p in ps}

    def mean_batch_size(self, stage: int | None = None) -> float:
        sizes = [b.size for b in self.batches
                 if stage is None or b.stage == stage]
        return float(np.mean(sizes)) if sizes else 0.0

    def queue_depths(self, stage: int) -> np.ndarray:
        return np.asarray([b.queue_depth for b in self.batches
                           if b.stage == stage], dtype=np.float64)

    def summary(self, now: float, *, stage_busy: list[float] | None = None,
                stage_capacity: list[float] | None = None) -> dict:
        """Roll-up of the whole run so far. ``stage_capacity`` = available
        replica-seconds per stage (integrated across reconfigurations).
        Null-safe: with zero completions the latency fields are None (JSON
        null), never NaN — a NaN in a benchmark JSON poisons every ratio
        gate comparison downstream (NaN < x is silently False)."""
        lat = self.latencies()
        pcts = {k: (None if np.isnan(v) else v)
                for k, v in self.latency_percentiles().items()}
        arrived = sum(self.arrival_counts.values())
        out = {
            "served": len(self.completions),
            "arrived": arrived,
            "shed": self.shed,
            "shed_rate": self.shed / max(arrived, 1),
            "throughput_rps": len(self.completions) / max(now, 1e-9),
            "latency_mean_s": float(lat.mean()) if lat.size else None,
            **pcts,
            "mean_batch_size": self.mean_batch_size(),
            "reconfigs": len(self.reconfigs),
        }
        if stage_busy is not None and stage_capacity is not None:
            out["utilization"] = [busy / max(cap, 1e-9)
                                  for busy, cap in zip(stage_busy,
                                                       stage_capacity,
                                                       strict=True)]
        return out
