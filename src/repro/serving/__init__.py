from repro.serving.batcher import (Batcher, ContinuousBatcher, Request,
                                   stack_tokens)
from repro.serving.engine import StageServer, PipelineServer
from repro.serving.arrivals import (ArrivalProcess, PoissonArrivals,
                                    TraceArrivals, BurstyArrivals,
                                    RampArrivals, make_arrivals,
                                    arrivals_from_dict, SCENARIOS)
from repro.serving.telemetry import Telemetry, percentile
from repro.serving.runtime import (ServingRuntime, RuntimeStage, EventLoop,
                                   COLD_START_SECONDS)
from repro.serving.fleet import (FleetRuntime, FleetTenant, build_fleet,
                                 scale_topology)
