from repro.serving.batcher import Batcher, Request
from repro.serving.engine import StageServer, PipelineServer
