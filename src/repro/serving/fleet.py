"""Multi-tenant fleet serving: N pipelines on one shared cluster.

A :class:`FleetRuntime` hosts N tenants — each a full closed-loop
``cluster.env.RuntimeEnv`` (pipeline + arrival process + telemetry) driven by
its own per-pipeline controller — on ONE shared :class:`EventLoop` and one
``ClusterTopology``. Three mechanisms knit them into a fleet:

- **Shared virtual timeline.** Every tenant's arrivals, batch dispatches and
  completions interleave on the same event heap, FIFO tie-broken by a global
  insertion sequence, so a fleet run is exactly as deterministic as a
  single-pipeline run. A fleet of one tenant *is* the historical
  single-pipeline runtime, event for event.

- **Priority-graded admission control.** Under overload (fleet-wide backlog
  against ``admission_limit``) the lowest priority class sheds first: a
  tenant at priority rank k of K admits only while the fleet backlog is
  below ``admission_limit * (k+1)/K``, so the highest class keeps admitting
  until the full limit. Shed requests are counted as offered load and
  reported as a per-tenant shed rate — they never enter a queue.

- **Fleet-level arbitration.** Before each adaptation interval the fleet
  re-divides the cluster between tenants proportionally to
  ``priority x predicted load`` (floored at ``min_share``): each tenant's
  controller then optimizes (variant, replicas, batch) against a
  capacity-scaled *view* of the cluster — the existing per-pipeline
  OPD/baseline controllers run unmodified within their allocation.

The interval protocol is two-phase: every tenant's action is applied
(``begin_step``) before the shared loop advances (one ``run_until``), then
every tenant scores its interval (``finish_step``) — so no tenant sees
another's reconfiguration land mid-interval.
"""
from __future__ import annotations

import math
from dataclasses import replace

from repro.cluster.topology import ClusterTopology
from repro.core.controller import decide
from repro.core.mdp import ADAPTATION_INTERVAL
from repro.serving.runtime import EventLoop

# Tenant shares are floor-quantized to this resolution before topologies are
# rebuilt: coarse shares keep the placement lru_cache from churning a fresh
# topology object every interval, and flooring keeps the sum <= 1.
SHARE_QUANTUM = 1e-4


def scale_topology(topo: ClusterTopology, share: float) -> ClusterTopology:
    """A tenant's view of the cluster: every node's capacity scaled by its
    fleet share. ``share >= 1.0`` returns ``topo`` itself (identity — the
    degenerate single-tenant fleet keeps the exact topology object, so
    placements and telemetry reproduce the standalone runtime bit-for-bit).
    """
    if share >= 1.0:
        return topo
    nodes = tuple(replace(n, capacity=n.capacity * share)
                  for n in topo.nodes)
    return ClusterTopology(name=f"{topo.name}@{share:.4f}", nodes=nodes,
                           hop_latency=topo.hop_latency)


class FleetTenant:
    """One tenant: a closed-loop env + its controller + fleet metadata.

    ``set_share`` rebinds the tenant's pipeline to a capacity-scaled view of
    the cluster — env, live runtime and controller all see the same scaled
    ``Pipeline`` (controllers keep a ``pipe`` attribute for their budget
    loops, so it must be rebound too)."""

    def __init__(self, name: str, env, controller, *, priority: int = 1,
                 slo_p99: float | None = None):
        self.name = name
        self.env = env
        self.controller = controller
        self.priority = int(priority)
        self.slo_p99 = slo_p99
        self.share = 1.0
        self._base_pipe = env.pipe          # full-cluster pipeline

    def set_share(self, share: float) -> bool:
        """Install a new cluster share; returns True when it changed."""
        if share == self.share:
            return False
        self.share = share
        base = self._base_pipe
        pipe = replace(base, w_max=base.w_max * share,
                       topology=scale_topology(base.topo, share))
        self.env.pipe = pipe
        self.env.runtime.pipe = pipe
        self.env.runtime.topo = pipe.topo
        if hasattr(self.controller, "pipe"):
            self.controller.pipe = pipe
        return True


class FleetRuntime:
    """N tenants sharing one event loop and one cluster topology."""

    def __init__(self, tenants: list[FleetTenant], *, loop: EventLoop,
                 admission_limit: float | None = None,
                 min_share: float = 0.08):
        self.tenants = list(tenants)
        self.loop = loop
        self.admission_limit = admission_limit
        self.min_share = float(min_share)
        self.reallocations = 0
        # admission fraction per tenant: rank of its priority among the
        # distinct priorities, scaled to (0, 1] — under a growing fleet
        # backlog the lowest class crosses its threshold (and sheds) first
        ranks = sorted({t.priority for t in self.tenants})
        self._frac = {t.name: (ranks.index(t.priority) + 1) / len(ranks)
                      for t in self.tenants}
        if admission_limit is not None:
            for t in self.tenants:
                t.env.runtime.admission = self._admission_for(t)

    # ------------------------------------------------- admission control --

    def backlog(self) -> int:
        """Fleet-wide in-system requests (arrived, not yet fully served)."""
        return sum(t.env.runtime.in_system for t in self.tenants)

    def _admission_for(self, tenant: FleetTenant):
        limit = float(self.admission_limit) * self._frac[tenant.name]

        def admit(_runtime, _req, limit=limit):
            return self.backlog() < limit

        return admit

    # -------------------------------------------------------- arbitration --

    def reallocate(self) -> int:
        """Re-divide the cluster: share proportional to priority x predicted
        load, floored at ``min_share``, floor-quantized. Returns the number
        of tenants whose share changed (0 for a single-tenant fleet after
        the first call — its share is always exactly 1.0).

        Demand is the load predicted over the *next adaptation interval* —
        horizon-matched through ``predicted_load_at`` when the tenant env
        carries a multi-horizon forecaster, which falls back to the
        single-horizon predictor / last-second load otherwise (shares are
        re-divided once per interval, so a last-second estimate lags a
        burst by a full interval)."""
        raw = [t.priority
               * max(float(t.env.predicted_load_at(ADAPTATION_INTERVAL)), 1.0)
               for t in self.tenants]
        total = sum(raw)
        shares = [max(r / total, self.min_share) for r in raw]
        total = sum(shares)
        shares = [math.floor(s / total / SHARE_QUANTUM) * SHARE_QUANTUM
                  for s in shares]
        changed = sum(t.set_share(s)
                      for t, s in zip(self.tenants, shares, strict=True))
        if changed:
            self.reallocations += 1
        return changed

    # ------------------------------------------------------ interval loop --

    def step_interval(self) -> dict:
        """One adaptation interval for the whole fleet: arbitrate shares,
        let every controller decide and apply (phase 1), advance the shared
        loop once (phase 2), then score every tenant (phase 3)."""
        self.reallocate()
        pendings = []
        for t in self.tenants:
            action = decide(t.controller, t.env)
            pendings.append(t.env.begin_step(action))
        self.loop.run_until(max(p[1] for p in pendings))
        out = {}
        for t, pending in zip(self.tenants, pendings, strict=True):
            _obs, r, done, info = t.env.finish_step(pending)
            out[t.name] = {"reward": float(r), "done": bool(done), **info}
        return out

    def drain(self):
        """Run the shared loop dry — every admitted request completes."""
        self.loop.drain()

    # ----------------------------------------------------------- queries --

    def summary(self) -> dict:
        """Per-tenant runtime summaries plus fleet-level totals."""
        tenants = {}
        offered = served = shed = 0
        for t in self.tenants:
            s = t.env.runtime.summary()
            s["priority"] = t.priority
            s["share"] = t.share
            if t.slo_p99 is not None:
                s["slo_p99"] = t.slo_p99
                s["slo_p99_met"] = (s["p99"] is not None
                                    and s["p99"] <= t.slo_p99)
            tenants[t.name] = s
            offered += s["arrived"]
            served += s["served"]
            shed += s["shed"]
        return {
            "fleet": {
                "tenants": len(self.tenants),
                "virtual_time_s": self.loop.now,
                "events": self.loop.events,
                "offered": offered,
                "served": served,
                "shed": shed,
                "shed_rate": shed / max(offered, 1),
                "reallocations": self.reallocations,
            },
            "tenants": tenants,
        }


def build_fleet(entries: list[dict], *, admission_limit: float | None = None,
                min_share: float = 0.08, horizon: int = 120,
                max_wait: float | None = None, seq_len: int = 32,
                weights=None, history: int = 120) -> FleetRuntime:
    """Assemble a fleet from tenant descriptions. Each entry is a dict with
    ``name``, ``pipe`` (carrying the *shared* cluster topology), ``arrivals``
    and ``controller``, plus optional ``priority``, ``slo_p99``,
    ``predictor`` and ``forecaster`` (multi-horizon; drives horizon-matched
    arbitration in ``reallocate``). Request ids are offset per tenant so
    completion records stay globally unique."""
    from repro.cluster.env import RuntimeEnv
    loop = EventLoop()
    tenants = []
    for i, e in enumerate(entries):
        env = RuntimeEnv(e["pipe"], e["arrivals"], horizon=horizon,
                         weights=weights, history=history,
                         predictor=e.get("predictor"),
                         forecaster=e.get("forecaster"),
                         max_wait=max_wait, seq_len=seq_len,
                         loop=loop, rid_base=i * 10_000_000)
        tenants.append(FleetTenant(e["name"], env, e["controller"],
                                   priority=e.get("priority", 1),
                                   slo_p99=e.get("slo_p99")))
    return FleetRuntime(tenants, loop=loop, admission_limit=admission_limit,
                        min_share=min_share)
