"""Pluggable arrival processes for the event-driven serving runtime.

Each process defines a per-second rate profile ``rates(horizon)`` (req/s)
and generates concrete arrival timestamps as a piecewise-homogeneous Poisson
process: for second ``s`` draw ``N ~ Poisson(rates[s])`` arrivals placed
uniformly inside ``[s, s+1)``. Deterministic per seed, so runtime runs are
reproducible and the environment can prefill the predictor's load history
with the expected-rate profile.
"""
from __future__ import annotations

import numpy as np


class ArrivalProcess:
    """Base: subclasses implement ``rates(horizon) -> [horizon] req/s``.

    Every process is a reproducible artifact: ``to_dict()`` captures its
    full parameterisation (JSON-safe) and ``from_dict`` rebuilds it, so a
    serialized experiment spec regenerates the identical arrival stream.
    """

    def __init__(self, *, seed: int = 0):
        self.seed = seed
        self._times_cache: dict[float, np.ndarray] = {}

    def rates(self, horizon: int) -> np.ndarray:
        raise NotImplementedError

    # ------------------------------------------------------ spec plumbing --
    _spec_fields: tuple[str, ...] = ()

    def to_dict(self) -> dict:
        out = {"kind": type(self).__name__, "seed": self.seed}
        for f in self._spec_fields:
            v = getattr(self, f)
            out[f] = v.tolist() if isinstance(v, np.ndarray) else v
        return out

    def times(self, horizon: float) -> np.ndarray:
        """The full event-time array for this process over [0, horizon):
        sorted arrival timestamps (virtual seconds), generated once and
        cached, so the Python event loop and the jitted runtime twin consume
        *identical* arrivals. The returned array is read-only — it is shared
        between callers.

        Generation is fully vectorized: all per-second Poisson counts in one
        draw, all uniform offsets in a second, instead of the historical
        per-second Python loop."""
        key = float(horizon)
        out = self._times_cache.get(key)
        if out is None:
            rng = np.random.default_rng(self.seed)
            seconds = int(np.ceil(horizon))
            lam = np.clip(np.asarray(self.rates(seconds), np.float64),
                          0.0, None)
            counts = rng.poisson(lam)
            total = int(counts.sum())
            if total == 0:
                out = np.empty(0, dtype=np.float64)
            else:
                base = np.repeat(np.arange(seconds, dtype=np.float64), counts)
                out = np.sort(base + rng.random(total))
                out = out[out < horizon]
            out.flags.writeable = False
            self._times_cache[key] = out
        return out

    def generate(self, horizon: float) -> np.ndarray:
        """Sorted arrival timestamps (virtual seconds) in [0, horizon)."""
        return self.times(horizon)


class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson process at ``rate`` req/s."""

    _spec_fields = ("rate",)

    def __init__(self, rate: float, *, seed: int = 0):
        super().__init__(seed=seed)
        self.rate = float(rate)

    def rates(self, horizon: int) -> np.ndarray:
        return np.full(horizon, self.rate)


class TraceArrivals(ArrivalProcess):
    """Trace-driven: per-second rates from a workload trace (req/s), e.g.
    ``cluster.workloads.make_trace``. The trace tiles if shorter than the
    horizon."""

    _spec_fields = ("trace",)

    def __init__(self, trace: np.ndarray, *, seed: int = 0):
        super().__init__(seed=seed)
        self.trace = np.asarray(trace, dtype=np.float64)

    def rates(self, horizon: int) -> np.ndarray:
        reps = int(np.ceil(horizon / len(self.trace)))
        return np.tile(self.trace, reps)[:horizon]


class BurstyArrivals(ArrivalProcess):
    """Diurnal sinusoid around ``base_rate`` with deterministic square bursts
    to ``burst_rate`` every ``period`` seconds for ``burst_len`` seconds —
    the adversarial pattern for a fixed provisioning policy."""

    _spec_fields = ("base_rate", "burst_rate", "period", "burst_len",
                    "diurnal_period")

    def __init__(self, base_rate: float, burst_rate: float, *,
                 period: float = 60.0, burst_len: float = 10.0,
                 diurnal_period: float = 300.0, seed: int = 0):
        super().__init__(seed=seed)
        self.base_rate = float(base_rate)
        self.burst_rate = float(burst_rate)
        self.period = float(period)
        self.burst_len = float(burst_len)
        self.diurnal_period = float(diurnal_period)

    def rates(self, horizon: int) -> np.ndarray:
        t = np.arange(horizon, dtype=np.float64)
        lam = self.base_rate * (1.0 + 0.25 * np.sin(
            2 * np.pi * t / self.diurnal_period))
        in_burst = (t % self.period) < self.burst_len
        lam[in_burst] = self.burst_rate
        return lam


class RampArrivals(ArrivalProcess):
    """Linear ramp from ``start_rate`` to ``end_rate`` over the horizon —
    exercises the controller's scale-up path."""

    _spec_fields = ("start_rate", "end_rate")

    def __init__(self, start_rate: float, end_rate: float, *, seed: int = 0):
        super().__init__(seed=seed)
        self.start_rate = float(start_rate)
        self.end_rate = float(end_rate)

    def rates(self, horizon: int) -> np.ndarray:
        return np.linspace(self.start_rate, self.end_rate, max(horizon, 1))


SCENARIOS = ("bursty", "poisson", "ramp", "trace")

_PROCESS_KINDS = {cls.__name__: cls for cls in
                  (PoissonArrivals, TraceArrivals, BurstyArrivals,
                   RampArrivals)}


def arrivals_from_dict(d: dict) -> ArrivalProcess:
    """Rebuild an ArrivalProcess from ``process.to_dict()`` output; the
    constructor kwargs come from each class's own ``_spec_fields``."""
    cls = _PROCESS_KINDS[d["kind"]]
    kwargs = {f: d[f] for f in cls._spec_fields}
    if "trace" in kwargs:
        kwargs["trace"] = np.asarray(kwargs["trace"], dtype=np.float64)
    return cls(**kwargs, seed=d.get("seed", 0))


def make_arrivals(scenario: str, *, rate: float = 25.0, seed: int = 0,
                  trace: np.ndarray | None = None) -> ArrivalProcess:
    """The named scenarios every driver (example, launcher, benchmark)
    shares, scaled around ``rate`` req/s. ``trace`` overrides the default
    fluctuating workload trace for the "trace" scenario."""
    if scenario == "poisson":
        return PoissonArrivals(rate, seed=seed)
    if scenario == "bursty":
        return BurstyArrivals(0.6 * rate, 1.8 * rate, period=60,
                              burst_len=10, seed=seed)
    if scenario == "ramp":
        return RampArrivals(0.2 * rate, 2.4 * rate, seed=seed)
    if scenario == "trace":
        if trace is None:
            # default fluctuating trace scaled so it peaks near ``rate`` —
            # the knob must act on every scenario, not silently no-op here
            from repro.cluster.workloads import make_trace
            trace = make_trace("fluctuating", seed=seed, peak=2.0 * rate) / 2.0
        return TraceArrivals(trace, seed=seed)
    raise ValueError(f"unknown arrival scenario {scenario!r}")
