"""Declarative experiment specs — plain frozen dataclasses, JSON-round-trip
safe, so an experiment is a reproducible artifact: ``Spec.from_dict(
spec.to_dict())`` equals the original, and running the reloaded spec
reproduces the run bit-for-bit (every random draw derives from spec seeds).

  PipelineSpec    stages × archs × quants × knob ranges  -> core Pipeline
  ScenarioSpec    arrival process + rate + seed + horizon -> ArrivalProcess
  ControllerSpec  which controller, its seed / training budget
  ExperimentSpec  the full run: pipeline + scenario + controller + backend
  TenantSpec      one fleet tenant: pipeline + scenario + controller
                  + priority class + latency SLO
  FleetSpec       N tenants sharing one cluster on one event loop
"""
from __future__ import annotations

# ``replace`` is re-exported through repro.api for spec overrides
from dataclasses import asdict, dataclass, replace  # noqa: F401

import numpy as np

from repro.cluster.topology import ClusterTopology, Node
from repro.cluster.workloads import WORKLOADS, make_trace
from repro.core.mdp import Pipeline
from repro.serving.arrivals import ArrivalProcess, TraceArrivals, make_arrivals

DEFAULT_QUANTS = ("bf16", "int8", "int4")


@dataclass(frozen=True)
class NodeSpec:
    """One edge device of a ClusterSpec, as data."""
    name: str
    capacity: float                  # chips this node contributes
    speed: float = 1.0               # service-rate factor of its device class
    device_class: str = "edge"

    def build(self) -> Node:
        return Node(name=self.name, capacity=self.capacity, speed=self.speed,
                    device_class=self.device_class)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> NodeSpec:
        return cls(name=d["name"], capacity=float(d["capacity"]),
                   speed=float(d.get("speed", 1.0)),
                   device_class=str(d.get("device_class", "edge")))


@dataclass(frozen=True)
class ClusterSpec:
    """A cluster topology — heterogeneous edge nodes plus the cross-node hop
    penalty — as JSON-round-trip data."""
    name: str
    nodes: tuple[NodeSpec, ...]
    hop_latency: float = 0.0         # s per adjacent-stage cross-node hop

    @property
    def total_capacity(self) -> float:
        return sum(n.capacity for n in self.nodes)

    def build(self) -> ClusterTopology:
        return ClusterTopology(name=self.name,
                               nodes=tuple(n.build() for n in self.nodes),
                               hop_latency=self.hop_latency)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> ClusterSpec:
        return cls(name=d["name"],
                   nodes=tuple(NodeSpec.from_dict(n) for n in d["nodes"]),
                   hop_latency=float(d.get("hop_latency", 0.0)))


@dataclass(frozen=True)
class PipelineSpec:
    """Stages × architectures × quantisation levels plus knob ranges —
    everything ``perf_model.make_pipeline`` needs, as data. ``cluster``
    (None = the homogeneous scalar pool of capacity ``w_max``) selects the
    cluster topology stage replicas are placed on; when set, the pipeline's
    W_max is the topology's total capacity.

    ``perf_source`` selects where variant latency coefficients come from:
    ``"analytic"`` (the default — pure ``perf_model`` arithmetic, bit-for-bit
    what every pre-calibration run used) or ``"calibrated"``, which rebinds
    the built pipeline onto measured ``(alpha, beta)`` from the calibration
    table named by ``calibration`` (a ``cluster.calibration.register_table``
    name or JSON path; None = the committed ``stage_calibration`` baseline).
    """
    name: str
    stages: tuple[tuple[str, ...], ...]      # arch names per stage
    quants: tuple[str, ...] = DEFAULT_QUANTS
    f_max: int = 8
    b_max: int = 32
    w_max: float = 64.0
    cluster: ClusterSpec | None = None
    perf_source: str = "analytic"            # "analytic" | "calibrated"
    calibration: str | None = None           # table name/path (calibrated)

    def build(self) -> Pipeline:
        from repro.cluster.perf_model import make_pipeline
        from repro.configs import ARCHS
        topology = self.cluster.build() if self.cluster else None
        w_max = self.cluster.total_capacity if self.cluster else self.w_max
        pipe = make_pipeline([[ARCHS[n] for n in names] for names in self.stages],
                             name=self.name, quants=self.quants,
                             f_max=self.f_max, b_max=self.b_max,
                             w_max=w_max, topology=topology)
        if self.perf_source == "analytic":
            return pipe
        if self.perf_source == "calibrated":
            from repro.cluster.calibration import (calibrate_pipeline,
                                                   resolve_table)
            return calibrate_pipeline(pipe, resolve_table(self.calibration))
        raise ValueError(f"unknown perf_source {self.perf_source!r} "
                         "(one of: analytic, calibrated)")

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> PipelineSpec:
        cluster = d.get("cluster")
        return cls(name=d["name"],
                   stages=tuple(tuple(s) for s in d["stages"]),
                   quants=tuple(d.get("quants", DEFAULT_QUANTS)),
                   f_max=int(d.get("f_max", 8)), b_max=int(d.get("b_max", 32)),
                   w_max=float(d.get("w_max", 64.0)),
                   cluster=ClusterSpec.from_dict(cluster) if cluster else None,
                   perf_source=str(d.get("perf_source", "analytic")),
                   calibration=d.get("calibration"))


@dataclass(frozen=True)
class PredictorSpec:
    """A load forecaster (``core/forecast.py``), as data: backbone family,
    forecast horizons, window geometry and training budget. ``scale`` is
    the load normaliser; 0.0 (the default) means "derive from the training
    traces" (their max, rounded up), so one spec serves any rate.

    Built via ``Session`` against the scenario's own arrival family
    (``ScenarioSpec.train_trace`` episodes), so the forecaster trains on
    the workload it will serve — never on the eval stream itself."""
    name: str
    backbone: str = "lstm"           # "lstm" (paper §IV-A) | "mlstm" (xLSTM)
    horizons: tuple[int, ...] = (5, 10, 20, 60)
    history: int = 120               # seconds of load history per window
    hidden: int = 25                 # LSTM units (paper: 25)
    dim: int = 16                    # mLSTM model dim
    n_heads: int = 2                 # mLSTM heads
    epochs: int = 8
    batch: int = 256
    lr: float = 5e-3
    seed: int = 0
    scale: float = 0.0               # 0.0 = auto from training traces
    train_episodes: int = 3          # training traces drawn from the scenario

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> PredictorSpec:
        return cls(name=d["name"], backbone=str(d.get("backbone", "lstm")),
                   horizons=tuple(int(h)
                                  for h in d.get("horizons", (5, 10, 20, 60))),
                   history=int(d.get("history", 120)),
                   hidden=int(d.get("hidden", 25)),
                   dim=int(d.get("dim", 16)),
                   n_heads=int(d.get("n_heads", 2)),
                   epochs=int(d.get("epochs", 8)),
                   batch=int(d.get("batch", 256)),
                   lr=float(d.get("lr", 5e-3)),
                   seed=int(d.get("seed", 0)),
                   scale=float(d.get("scale", 0.0)),
                   train_episodes=int(d.get("train_episodes", 3)))


@dataclass(frozen=True)
class ScenarioSpec:
    """A workload: arrival kind (any of serving ``SCENARIOS`` or a paper
    workload regime from ``WORKLOADS``), its rate scale, seed and horizon.
    For workload regimes ``rate`` is the trace's peak (paper default 120).

    ``predictor`` optionally names a registered ``PredictorSpec``: the
    Session trains that forecaster on this scenario's arrival family and
    attaches it to the built env (multi-horizon forecasts on every
    Observation; horizon-matched ``predicted_load``)."""
    kind: str = "bursty"
    rate: float = 25.0
    seed: int = 0
    horizon: int = 120
    predictor: str | None = None

    def build_arrivals(self) -> ArrivalProcess:
        if self.kind in WORKLOADS:
            return TraceArrivals(make_trace(self.kind, seed=self.seed,
                                            peak=self.rate), seed=self.seed)
        return make_arrivals(self.kind, rate=self.rate, seed=self.seed)

    def eval_trace(self) -> np.ndarray:
        """Per-second rate profile over the horizon — the analytic
        backend's workload trace."""
        if self.kind in WORKLOADS:
            return make_trace(self.kind, seed=self.seed, peak=self.rate,
                              seconds=self.horizon)
        return self.build_arrivals().rates(self.horizon)

    def train_arrivals(self, episode: int) -> ArrivalProcess:
        """Arrival process for runtime-twin PPO episode ``episode`` — the
        scenario's own arrival family at the scenario rate, with a seed
        decorrelated from the eval stream and across episodes."""
        seed = self.seed + 7919 * (episode + 1)
        if self.kind in WORKLOADS:
            return TraceArrivals(make_trace(self.kind, seed=seed,
                                            peak=self.rate), seed=seed)
        return make_arrivals(self.kind, rate=self.rate, seed=seed)

    def train_trace(self, episode: int, *, seconds: int = 1200) -> np.ndarray:
        """Training trace for PPO episode ``episode`` — covers the demand
        levels the scenario will serve, decorrelated across episodes."""
        if self.kind in WORKLOADS:
            return make_trace(self.kind, seed=episode, peak=self.rate,
                              seconds=seconds)
        base = self.build_arrivals().rates(seconds)
        return np.roll(base, 37 * episode)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> ScenarioSpec:
        return cls(kind=d["kind"], rate=float(d.get("rate", 25.0)),
                   seed=int(d.get("seed", 0)),
                   horizon=int(d.get("horizon", 120)),
                   predictor=d.get("predictor"))


@dataclass(frozen=True)
class ControllerSpec:
    """Which controller runs the loop, and every knob that affects its
    decisions: RNG seed, OPD decode mode and PPO training budget."""
    name: str = "greedy"
    seed: int = 0
    greedy: bool = True          # OPD decode mode (argmax vs sample)
    train_episodes: int = 0      # PPO episodes before serving (OPD only)
    train_seconds: int = 1200    # length of each training trace
    expert_freq: int = 2         # Alg. 2 expert-guided episode frequency
    num_envs: int = 1            # parallel envs per PPO episode (>1 with
    #                              the analytic backend -> core.vecenv)
    train_backend: str = "analytic"  # what on-policy episodes roll on:
    #                              "analytic" (closed-form PipelineEnv) or
    #                              "runtime" (core.runtime_vec, the jitted
    #                              discrete-event twin of ServingRuntime)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> ControllerSpec:
        return cls(name=d["name"], seed=int(d.get("seed", 0)),
                   greedy=bool(d.get("greedy", True)),
                   train_episodes=int(d.get("train_episodes", 0)),
                   train_seconds=int(d.get("train_seconds", 1200)),
                   expert_freq=int(d.get("expert_freq", 2)),
                   num_envs=int(d.get("num_envs", 1)),
                   train_backend=str(d.get("train_backend", "analytic")))


@dataclass(frozen=True)
class ExperimentSpec:
    """One full run. ``backend`` selects the simulator: "runtime" steps the
    event-driven ServingRuntime (measured telemetry), "analytic" steps the
    closed-form PipelineEnv (cheap, used for training). ``real`` attaches
    live smoke-scale JAX models as stage executors (runtime backend only)."""
    pipeline: PipelineSpec
    scenario: ScenarioSpec
    controller: ControllerSpec
    backend: str = "runtime"     # "runtime" | "analytic"
    real: bool = False
    seq_len: int = 32

    def to_dict(self) -> dict:
        return {"pipeline": self.pipeline.to_dict(),
                "scenario": self.scenario.to_dict(),
                "controller": self.controller.to_dict(),
                "backend": self.backend, "real": self.real,
                "seq_len": self.seq_len}

    @classmethod
    def from_dict(cls, d: dict) -> ExperimentSpec:
        return cls(pipeline=PipelineSpec.from_dict(d["pipeline"]),
                   scenario=ScenarioSpec.from_dict(d["scenario"]),
                   controller=ControllerSpec.from_dict(d["controller"]),
                   backend=d.get("backend", "runtime"),
                   real=bool(d.get("real", False)),
                   seq_len=int(d.get("seq_len", 32)))


@dataclass(frozen=True)
class TenantSpec:
    """One fleet tenant: its pipeline (rebound onto the fleet's shared
    cluster at build time), workload, per-pipeline controller, priority
    class (higher admits longer under overload and weighs heavier in the
    fleet's capacity arbitration) and an optional p99 latency SLO (seconds)
    reported against measured telemetry."""
    name: str
    pipeline: PipelineSpec
    scenario: ScenarioSpec
    controller: ControllerSpec
    priority: int = 1
    slo_p99: float | None = None

    def to_dict(self) -> dict:
        return {"name": self.name, "pipeline": self.pipeline.to_dict(),
                "scenario": self.scenario.to_dict(),
                "controller": self.controller.to_dict(),
                "priority": self.priority, "slo_p99": self.slo_p99}

    @classmethod
    def from_dict(cls, d: dict) -> TenantSpec:
        slo = d.get("slo_p99")
        return cls(name=d["name"],
                   pipeline=PipelineSpec.from_dict(d["pipeline"]),
                   scenario=ScenarioSpec.from_dict(d["scenario"]),
                   controller=ControllerSpec.from_dict(d["controller"]),
                   priority=int(d.get("priority", 1)),
                   slo_p99=None if slo is None else float(slo))


@dataclass(frozen=True)
class FleetSpec:
    """N tenants multiplexed onto one shared cluster and one virtual-time
    event loop. ``admission_limit`` is the fleet-wide backlog ceiling the
    priority-graded load shedder works against (None = never shed);
    ``min_share`` floors every tenant's slice of the cluster so arbitration
    cannot starve a quiet tenant."""
    name: str
    cluster: ClusterSpec
    tenants: tuple[TenantSpec, ...]
    admission_limit: float | None = None
    min_share: float = 0.08
    seq_len: int = 32

    @property
    def horizon(self) -> int:
        """Fleet serving horizon: the longest tenant scenario."""
        return max(t.scenario.horizon for t in self.tenants)

    def tenant_pipeline(self, tenant: TenantSpec) -> PipelineSpec:
        """The tenant's pipeline rebound onto the fleet's shared cluster."""
        return replace(tenant.pipeline, cluster=self.cluster)

    def to_dict(self) -> dict:
        return {"name": self.name, "cluster": self.cluster.to_dict(),
                "tenants": [t.to_dict() for t in self.tenants],
                "admission_limit": self.admission_limit,
                "min_share": self.min_share, "seq_len": self.seq_len}

    @classmethod
    def from_dict(cls, d: dict) -> FleetSpec:
        limit = d.get("admission_limit")
        return cls(name=d["name"],
                   cluster=ClusterSpec.from_dict(d["cluster"]),
                   tenants=tuple(TenantSpec.from_dict(t)
                                 for t in d["tenants"]),
                   admission_limit=None if limit is None else float(limit),
                   min_share=float(d.get("min_share", 0.08)),
                   seq_len=int(d.get("seq_len", 32)))
