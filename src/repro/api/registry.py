"""Named registries for pipelines, scenarios and controllers.

Registering makes a spec discoverable by name (``get_* `` / ``list_*``), so
entry points build everything as data instead of copy-pasted wiring:

    exp = ExperimentSpec(pipeline=get_pipeline("serve2"),
                         scenario=get_scenario("bursty"),
                         controller=get_controller("opd"))

Controllers additionally register a *factory* ``(spec, pipe, params) ->
controller instance`` used by the Session when serving starts; ``params`` is
the trained policy state for learned controllers (None otherwise).
"""
from __future__ import annotations

from repro.cluster.workloads import WORKLOADS
from repro.serving.arrivals import SCENARIOS

from repro.api.specs import ControllerSpec, PipelineSpec, ScenarioSpec

_PIPELINES: dict[str, PipelineSpec] = {}
_SCENARIOS: dict[str, ScenarioSpec] = {}
_CONTROLLERS: dict[str, tuple[ControllerSpec, object]] = {}


# ---------------------------------------------------------------- pipelines --

def register_pipeline(spec: PipelineSpec, *, name: str | None = None) -> PipelineSpec:
    _PIPELINES[name or spec.name] = spec
    return spec


def get_pipeline(name: str) -> PipelineSpec:
    try:
        return _PIPELINES[name]
    except KeyError:
        raise KeyError(f"unknown pipeline {name!r}; "
                       f"registered: {list_pipelines()}") from None


def list_pipelines() -> tuple[str, ...]:
    return tuple(sorted(_PIPELINES))


# ---------------------------------------------------------------- scenarios --

def register_scenario(name: str, spec: ScenarioSpec) -> ScenarioSpec:
    _SCENARIOS[name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {list_scenarios()}") from None


def list_scenarios() -> tuple[str, ...]:
    return tuple(sorted(_SCENARIOS))


# -------------------------------------------------------------- controllers --

def register_controller(name: str, factory, *,
                        spec: ControllerSpec | None = None) -> None:
    """``factory(spec, pipe, params) -> controller``; ``spec`` is the default
    ControllerSpec handed out by ``get_controller(name)``."""
    _CONTROLLERS[name] = (spec or ControllerSpec(name=name), factory)


def get_controller(name: str) -> ControllerSpec:
    try:
        return _CONTROLLERS[name][0]
    except KeyError:
        raise KeyError(f"unknown controller {name!r}; "
                       f"registered: {list_controllers()}") from None


def controller_factory(name: str):
    return _CONTROLLERS[name][1]


def list_controllers() -> tuple[str, ...]:
    return tuple(sorted(_CONTROLLERS))


# ---------------------------------------------------------------- built-ins --

def _register_builtin_pipelines():
    # the paper's 4-stage pipeline (perf_model.default_pipeline as data)
    register_pipeline(PipelineSpec(
        name="paper-4stage",
        stages=(("whisper-small", "xlstm-125m"),
                ("llama3.2-1b", "starcoder2-3b"),
                ("granite-moe-3b-a800m", "zamba2-2.7b"),
                ("granite-3-8b", "llava-next-mistral-7b"))))
    # the launcher's 2-stage serving pipeline
    register_pipeline(PipelineSpec(
        name="serve2",
        stages=(("whisper-small", "xlstm-125m"),
                ("llama3.2-1b", "starcoder2-3b")),
        quants=("bf16",)))
    # the closed-loop demo / runtime-benchmark 3-stage pipeline
    register_pipeline(PipelineSpec(
        name="serve3",
        stages=(("xlstm-125m", "whisper-small"),
                ("llama3.2-1b", "starcoder2-3b"),
                ("granite-moe-3b-a800m", "zamba2-2.7b")),
        quants=("bf16",)))


def _register_builtin_scenarios():
    for kind in SCENARIOS:          # event-driven arrival processes
        register_scenario(kind, ScenarioSpec(kind=kind, rate=25.0, seed=0,
                                             horizon=120))
    for kind in WORKLOADS:          # the paper's Fig. 4 workload regimes
        register_scenario(kind, ScenarioSpec(kind=kind, rate=120.0, seed=0,
                                             horizon=1200))


def _register_builtin_controllers():
    from repro.core.baselines import GreedyPolicy, IPAPolicy, RandomPolicy
    from repro.core.expert import ExpertPolicy
    from repro.core.opd import OPDPolicy

    register_controller(
        "opd", lambda spec, pipe, params: OPDPolicy(
            pipe, params, greedy=spec.greedy, seed=spec.seed),
        spec=ControllerSpec(name="opd", train_episodes=4, num_envs=4))
    register_controller("greedy", lambda spec, pipe, params: GreedyPolicy(pipe))
    register_controller(
        "ipa", lambda spec, pipe, params: IPAPolicy(pipe))
    register_controller(
        "random", lambda spec, pipe, params: RandomPolicy(pipe, seed=spec.seed))
    register_controller(
        "expert", lambda spec, pipe, params: ExpertPolicy(pipe))


_register_builtin_pipelines()
_register_builtin_scenarios()
_register_builtin_controllers()
