"""Named registries for pipelines, scenarios and controllers.

Registering makes a spec discoverable by name (``get_* `` / ``list_*``), so
entry points build everything as data instead of copy-pasted wiring:

    exp = ExperimentSpec(pipeline=get_pipeline("serve2"),
                         scenario=get_scenario("bursty"),
                         controller=get_controller("opd"))

Controllers additionally register a *factory* ``(spec, pipe, params) ->
controller instance`` used by the Session when serving starts; ``params`` is
the trained policy state for learned controllers (None otherwise).
"""
from __future__ import annotations

from repro.cluster.workloads import WORKLOADS
from repro.serving.arrivals import SCENARIOS

from repro.api.specs import (ClusterSpec, ControllerSpec, FleetSpec,
                             NodeSpec, PipelineSpec, PredictorSpec,
                             ScenarioSpec, TenantSpec)

_PIPELINES: dict[str, PipelineSpec] = {}
_SCENARIOS: dict[str, ScenarioSpec] = {}
_CONTROLLERS: dict[str, tuple[ControllerSpec, object]] = {}
_CLUSTERS: dict[str, ClusterSpec] = {}
_FLEETS: dict[str, FleetSpec] = {}
_PREDICTORS: dict[str, PredictorSpec] = {}


# ---------------------------------------------------------------- pipelines --

def register_pipeline(spec: PipelineSpec, *, name: str | None = None) -> PipelineSpec:
    _PIPELINES[name or spec.name] = spec
    return spec


def get_pipeline(name: str) -> PipelineSpec:
    try:
        return _PIPELINES[name]
    except KeyError:
        raise KeyError(f"unknown pipeline {name!r}; "
                       f"registered: {list_pipelines()}") from None


def list_pipelines() -> tuple[str, ...]:
    return tuple(sorted(_PIPELINES))


# ---------------------------------------------------------------- scenarios --

def register_scenario(name: str, spec: ScenarioSpec) -> ScenarioSpec:
    _SCENARIOS[name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {list_scenarios()}") from None


def list_scenarios() -> tuple[str, ...]:
    return tuple(sorted(_SCENARIOS))


# ----------------------------------------------------------------- clusters --

def register_cluster(spec: ClusterSpec, *, name: str | None = None) -> ClusterSpec:
    _CLUSTERS[name or spec.name] = spec
    return spec


def get_cluster(name: str) -> ClusterSpec:
    try:
        return _CLUSTERS[name]
    except KeyError:
        raise KeyError(f"unknown cluster {name!r}; "
                       f"registered: {list_clusters()}") from None


def list_clusters() -> tuple[str, ...]:
    return tuple(sorted(_CLUSTERS))


# ------------------------------------------------------------------- fleets --

def register_fleet(spec: FleetSpec, *, name: str | None = None) -> FleetSpec:
    _FLEETS[name or spec.name] = spec
    return spec


def get_fleet(name: str) -> FleetSpec:
    try:
        return _FLEETS[name]
    except KeyError:
        raise KeyError(f"unknown fleet {name!r}; "
                       f"registered: {list_fleets()}") from None


def list_fleets() -> tuple[str, ...]:
    return tuple(sorted(_FLEETS))


# --------------------------------------------------------------- predictors --

def register_predictor(spec: PredictorSpec, *,
                       name: str | None = None) -> PredictorSpec:
    _PREDICTORS[name or spec.name] = spec
    return spec


def get_predictor(name: str) -> PredictorSpec:
    try:
        return _PREDICTORS[name]
    except KeyError:
        raise KeyError(f"unknown predictor {name!r}; "
                       f"registered: {list_predictors()}") from None


def list_predictors() -> tuple[str, ...]:
    return tuple(sorted(_PREDICTORS))


# -------------------------------------------------------------- controllers --

def register_controller(name: str, factory, *,
                        spec: ControllerSpec | None = None) -> None:
    """``factory(spec, pipe, params) -> controller``; ``spec`` is the default
    ControllerSpec handed out by ``get_controller(name)``."""
    _CONTROLLERS[name] = (spec or ControllerSpec(name=name), factory)


def get_controller(name: str) -> ControllerSpec:
    try:
        return _CONTROLLERS[name][0]
    except KeyError:
        raise KeyError(f"unknown controller {name!r}; "
                       f"registered: {list_controllers()}") from None


def controller_factory(name: str):
    return _CONTROLLERS[name][1]


def list_controllers() -> tuple[str, ...]:
    return tuple(sorted(_CONTROLLERS))


# ---------------------------------------------------------------- built-ins --

def _register_builtin_clusters():
    # the paper's cluster: one homogeneous scalar pool of W_max = 64 chips —
    # the default every existing pipeline implicitly runs on
    register_cluster(ClusterSpec(
        name="homogeneous",
        nodes=(NodeSpec("edge-0", capacity=64.0),)))
    # a big/medium/small edge cell (EdgeSight-style heterogeneous fleet):
    # same 64-chip total as the paper's pool, but fragmented across device
    # classes with different service speeds and a 20 ms cross-node hop
    register_cluster(ClusterSpec(
        name="edge-hetero-3",
        nodes=(NodeSpec("big", capacity=32.0, speed=1.25,
                        device_class="server"),
               NodeSpec("medium", capacity=20.0, speed=1.0,
                        device_class="edge-box"),
               NodeSpec("small", capacity=12.0, speed=0.7,
                        device_class="device")),
        hop_latency=0.02))
    # a tightly constrained two-device cell: little total capacity, slow
    # devices, expensive hops — placement pressure dominates every decision
    register_cluster(ClusterSpec(
        name="edge-constrained",
        nodes=(NodeSpec("cell-a", capacity=12.0, speed=0.8,
                        device_class="device"),
               NodeSpec("cell-b", capacity=8.0, speed=0.6,
                        device_class="device")),
        hop_latency=0.05))


def _register_builtin_pipelines():
    # the paper's 4-stage pipeline (perf_model.default_pipeline as data)
    register_pipeline(PipelineSpec(
        name="paper-4stage",
        stages=(("whisper-small", "xlstm-125m"),
                ("llama3.2-1b", "starcoder2-3b"),
                ("granite-moe-3b-a800m", "zamba2-2.7b"),
                ("granite-3-8b", "llava-next-mistral-7b"))))
    # the launcher's 2-stage serving pipeline
    register_pipeline(PipelineSpec(
        name="serve2",
        stages=(("whisper-small", "xlstm-125m"),
                ("llama3.2-1b", "starcoder2-3b")),
        quants=("bf16",)))
    # the closed-loop demo / runtime-benchmark 3-stage pipeline
    register_pipeline(PipelineSpec(
        name="serve3",
        stages=(("xlstm-125m", "whisper-small"),
                ("llama3.2-1b", "starcoder2-3b"),
                ("granite-moe-3b-a800m", "zamba2-2.7b")),
        quants=("bf16",)))
    # the same 3-stage pipeline on the heterogeneous big/medium/small edge
    # cell — placement-aware physics (node speeds, per-node feasibility,
    # cross-node hops) and the per-node Eq. (5) state extension
    register_pipeline(PipelineSpec(
        name="serve3-hetero",
        stages=(("xlstm-125m", "whisper-small"),
                ("llama3.2-1b", "starcoder2-3b"),
                ("granite-moe-3b-a800m", "zamba2-2.7b")),
        quants=("bf16",),
        cluster=_CLUSTERS["edge-hetero-3"]))


def _register_builtin_scenarios():
    for kind in SCENARIOS:          # event-driven arrival processes
        register_scenario(kind, ScenarioSpec(kind=kind, rate=25.0, seed=0,
                                             horizon=120))
    for kind in WORKLOADS:          # the paper's Fig. 4 workload regimes
        register_scenario(kind, ScenarioSpec(kind=kind, rate=120.0, seed=0,
                                             horizon=1200))


def _register_builtin_fleets():
    # three tenant classes sharing the heterogeneous big/medium/small edge
    # cell: a latency-critical interactive tenant (highest priority, tight
    # p99 SLO), a steady analytics tenant, and a best-effort batch tenant
    # (lowest priority — first to shed under fleet overload)
    register_fleet(FleetSpec(
        name="fleet-3tenant-hetero",
        cluster=_CLUSTERS["edge-hetero-3"],
        admission_limit=400.0,
        tenants=(
            TenantSpec(name="interactive",
                       pipeline=_PIPELINES["serve2"],
                       scenario=ScenarioSpec(kind="bursty", rate=25.0,
                                             seed=0, horizon=120),
                       controller=ControllerSpec(name="greedy"),
                       priority=3, slo_p99=2.0),
            TenantSpec(name="analytics",
                       pipeline=_PIPELINES["serve3"],
                       scenario=ScenarioSpec(kind="poisson", rate=15.0,
                                             seed=1, horizon=120),
                       controller=ControllerSpec(name="ipa"),
                       priority=2, slo_p99=5.0),
            TenantSpec(name="batch",
                       pipeline=_PIPELINES["serve2"],
                       scenario=ScenarioSpec(kind="ramp", rate=20.0,
                                             seed=2, horizon=120),
                       controller=ControllerSpec(name="greedy"),
                       priority=1),
        )))


def _register_builtin_predictors():
    # the paper's §IV-A predictor as a forecaster: 25-unit LSTM, single
    # 20 s horizon — a drop-in for core/predictor.py through the spec path
    register_predictor(PredictorSpec(name="lstm-20s", backbone="lstm",
                                     horizons=(20,)))
    # paper-faithful LSTM emitting every proactive-control horizon from one
    # backbone pass — what the pre-warm baseline consumes by default
    register_predictor(PredictorSpec(name="lstm-multi", backbone="lstm",
                                     horizons=(5, 10, 20, 60)))
    # the xLSTM matrix-memory backbone (nn/xlstm.py) at the same horizons —
    # parallelisable over the window; needs a longer schedule to converge
    register_predictor(PredictorSpec(name="mlstm-multi", backbone="mlstm",
                                     horizons=(5, 10, 20, 60),
                                     epochs=20, lr=3e-3))


def _register_builtin_controllers():
    from repro.core.baselines import GreedyPolicy, IPAPolicy, RandomPolicy
    from repro.core.expert import CapacityPolicy, ExpertPolicy
    from repro.core.opd import OPDPolicy
    from repro.core.proactive import ProactiveController

    register_controller(
        "opd", lambda spec, pipe, params: OPDPolicy(
            pipe, params, greedy=spec.greedy, seed=spec.seed),
        spec=ControllerSpec(name="opd", train_episodes=4, num_envs=4))
    register_controller("greedy", lambda spec, pipe, params: GreedyPolicy(pipe))
    register_controller(
        "ipa", lambda spec, pipe, params: IPAPolicy(pipe))
    register_controller(
        "random", lambda spec, pipe, params: RandomPolicy(pipe, seed=spec.seed))
    register_controller(
        "expert", lambda spec, pipe, params: ExpertPolicy(pipe))
    # demand-matched min-cost: cheapest demand-covering config over the FULL
    # variant space — variants switch with load (greedy's stay pinned)
    register_controller(
        "capacity", lambda spec, pipe, params: CapacityPolicy(pipe))
    # forecast-driven pre-warm wrapper around a trained OPD policy: same
    # training path as "opd", plus a prewarm_plan consumed by RuntimeEnv
    register_controller(
        "proactive", lambda spec, pipe, params: ProactiveController(
            OPDPolicy(pipe, params, greedy=spec.greedy, seed=spec.seed)),
        spec=ControllerSpec(name="proactive", train_episodes=4, num_envs=4))
    # the same wrapper around the demand-matched analytic expert — the
    # expert re-sizes (variant, replicas, batch) with predicted load, so the
    # forecast moves real capacity ahead of a burst and the pre-warm slot
    # absorbs the variant-switch cold start (fig45 proactive comparison)
    register_controller(
        "proactive-expert",
        lambda spec, pipe, params: ProactiveController(ExpertPolicy(pipe)))
    # the headline fig45 proactive arm: min-cost inner, so the forecast's
    # early variant switches are pre-warmed at a config cost below the
    # reactive baselines (accuracy-first experts overspend on ramps)
    register_controller(
        "proactive-capacity",
        lambda spec, pipe, params: ProactiveController(CapacityPolicy(pipe)))


_register_builtin_clusters()
_register_builtin_pipelines()
_register_builtin_scenarios()
_register_builtin_fleets()
_register_builtin_predictors()
_register_builtin_controllers()
