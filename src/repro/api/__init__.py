"""repro.api — the declarative control-plane API.

Specs (`PipelineSpec`, `ScenarioSpec`, `ControllerSpec`, `ExperimentSpec`)
describe an experiment as JSON-serializable data; registries name the
built-ins (`get_pipeline("paper-4stage")`, `get_scenario("bursty")`,
`get_controller("opd")`); the `Session` facade owns the env / runtime /
predictor / policy lifecycle. See docs/API.md for the schema and quickstart.
"""
from repro.api.specs import (ClusterSpec, ControllerSpec, ExperimentSpec,
                             FleetSpec, NodeSpec, PipelineSpec, PredictorSpec,
                             ScenarioSpec, TenantSpec, replace)
from repro.api.registry import (register_pipeline, register_scenario,
                                register_controller, register_cluster,
                                register_fleet, register_predictor,
                                get_pipeline, get_scenario,
                                get_controller, get_cluster, get_fleet,
                                get_predictor, controller_factory,
                                list_pipelines,
                                list_scenarios, list_controllers,
                                list_clusters, list_fleets, list_predictors)
from repro.api.session import (Session, FleetSession, build_executors,
                               run_experiment)
from repro.core.controller import Controller, ControllerBase, Observation, decide
