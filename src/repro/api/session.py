"""The Session facade — owns the env / runtime / predictor / policy
lifecycle that entry points used to wire by hand:

    sess = Session.from_spec(exp)     # ExperimentSpec, dict, or JSON str
    sess.train(log=print)             # PPO episodes (no-op for baselines)
    sess.serve(on_step=...)           # run the control loop over the horizon
    sess.report()                     # JSON-safe results incl. the spec

Every random draw (arrival stream, request tokens, policy sampling, PPO
training) derives from the spec's seeds, so serializing a spec to JSON and
reloading it reproduces the run bit-for-bit.
"""
from __future__ import annotations

import contextlib
import json
import time

import numpy as np

from repro.analysis import sanitize
from repro.cluster.env import PipelineEnv, RuntimeEnv
from repro.core.controller import decide
from repro.core.ppo import OPDTrainer, PPOConfig

from repro.api.registry import controller_factory
from repro.api.specs import ExperimentSpec, FleetSpec

# per-step scalar keys copied into the report (runtime adds percentiles etc.)
_STEP_KEYS = ("qos", "cost", "latency", "throughput", "excess", "demand")
_TRAINABLE = ("opd", "proactive")


def build_executors(spec: ExperimentSpec):
    """Live smoke-scale JAX models as stage executors for ``real`` runs."""
    from repro.configs import ARCHS
    from repro.serving.engine import StageServer
    servers = [StageServer(f"stage{i}", [ARCHS[n].smoke() for n in names],
                           seq_len=spec.seq_len, seed=i)
               for i, names in enumerate(spec.pipeline.stages)]
    return [s.execute for s in servers]


class Session:
    def __init__(self, spec: ExperimentSpec, *, debug_checkify: bool = False):
        self.spec = spec
        self.pipe = spec.pipeline.build()
        self.trainer: OPDTrainer | None = None
        self.controller = None
        self._params = None
        self._forecaster = None         # trained once, shared across envs
        self._report: dict | None = None
        # debug toggle: run every twin rollout under the checkify sanitizer
        # (NaN / div / OOB surface as JaxRuntimeError instead of reward
        # drift) — see repro.analysis.sanitize; also reachable via the
        # REPRO_CHECKIFY=1 env flag without touching call sites
        self.debug_checkify = debug_checkify

    def _sanitize_scope(self):
        return (sanitize.enabled_scope(True) if self.debug_checkify
                else contextlib.nullcontext())

    # ------------------------------------------------------------ creation --

    @classmethod
    def from_spec(cls, spec: ExperimentSpec | dict | str, *,
                  debug_checkify: bool = False) -> Session:
        if isinstance(spec, str):
            spec = json.loads(spec)
        if isinstance(spec, dict):
            spec = ExperimentSpec.from_dict(spec)
        return cls(spec, debug_checkify=debug_checkify)

    # ------------------------------------------------------------ training --

    @property
    def trainable(self) -> bool:
        return self.spec.controller.name in _TRAINABLE

    def train(self, episodes: int | None = None, *, log=None) -> Session:
        """Run PPO training for learned controllers; no-op for baselines.
        The controller's ``train_backend`` picks what on-policy episodes
        roll on: "analytic" steps the closed-form ``PipelineEnv`` (optionally
        vectorized via ``num_envs``), "runtime" rolls closed-loop episodes
        on the jitted discrete-event twin (``core.runtime_vec``) — expert
        episodes always step a real env. Fully seeded from the spec."""
        c, scen = self.spec.controller, self.spec.scenario
        episodes = c.train_episodes if episodes is None else episodes
        if not self.trainable or episodes <= 0:
            return self
        runtime_backend = c.train_backend == "runtime"
        if c.train_backend not in ("analytic", "runtime"):
            raise ValueError(f"unknown train_backend {c.train_backend!r}")

        def make_env(seed):
            if runtime_backend:
                return RuntimeEnv(self.pipe, scen.train_arrivals(seed),
                                  horizon=scen.horizon)
            return PipelineEnv(self.pipe,
                               scen.train_trace(seed, seconds=c.train_seconds),
                               seed=seed)

        if self.trainer is None:
            self.trainer = OPDTrainer(
                self.pipe, make_env,
                ppo=PPOConfig(expert_freq=c.expert_freq), seed=c.seed,
                num_envs=c.num_envs,
                vec_runtime=scen.train_arrivals if runtime_backend else None)
        with self._sanitize_scope():
            for ep in range(1, episodes + 1):
                self.trainer.train_episode(ep, env_seed=ep)
                if log:
                    h = self.trainer.history
                    log(f"episode {ep}: reward={h['reward'][-1]:9.2f} "
                        f"loss={h['loss'][-1]:7.3f} expert={h['expert'][-1]}")
        self.controller = None          # params changed -> rebuild on serve
        return self

    # ------------------------------------------------------------- serving --

    def build_forecaster(self, *, log=None):
        """Train the scenario's named ``PredictorSpec`` (once per session,
        cached) on the scenario's *own arrival family* — per-second counts
        Poisson-sampled from ``train_trace`` episode rate profiles, so the
        model sees the integer-valued histories the Monitor will feed it,
        decorrelated from the eval stream. Returns an ``as_forecast_fn``
        adapter, or None when the scenario names no predictor."""
        scen = self.spec.scenario
        if scen.predictor is None:
            return None
        if self._forecaster is None:
            from repro.api.registry import get_predictor
            from repro.core import forecast
            ps = get_predictor(scen.predictor)
            traces = []
            for ep in range(ps.train_episodes):
                rates = np.maximum(scen.train_trace(ep), 0.0)
                rng = np.random.default_rng(scen.seed + 104729 * (ep + 1))
                traces.append(rng.poisson(rates).astype(np.float32))
            scale = ps.scale or float(max(max(tr.max() for tr in traces), 1.0))
            params, ch_scales = forecast.train_forecaster(
                traces, backbone=ps.backbone, scale=scale,
                horizons=ps.horizons, history=ps.history, hidden=ps.hidden,
                dim=ps.dim, n_heads=ps.n_heads, epochs=ps.epochs,
                batch=ps.batch, lr=ps.lr, seed=ps.seed, log=log)
            self._forecaster = forecast.as_forecast_fn(
                params, scale=scale, backbone=ps.backbone,
                horizons=ps.horizons, history=ps.history,
                n_heads=ps.n_heads, channel_scales=ch_scales)
        return self._forecaster

    def build_env(self):
        spec, scen = self.spec, self.spec.scenario
        forecaster = self.build_forecaster()
        if spec.backend == "analytic":
            return PipelineEnv(self.pipe, scen.eval_trace(), seed=scen.seed,
                               forecaster=forecaster)
        if spec.backend == "runtime":
            executors = build_executors(spec) if spec.real else None
            return RuntimeEnv(self.pipe, scen.build_arrivals(),
                              horizon=scen.horizon, executors=executors,
                              seq_len=spec.seq_len, forecaster=forecaster)
        raise ValueError(f"unknown backend {spec.backend!r}")

    def with_params(self, params) -> Session:
        """Attach pre-trained policy params (skips in-session training) —
        lets callers share one trained agent across many sessions."""
        self._params = params
        self.controller = None
        return self

    def build_controller(self):
        c = self.spec.controller
        params = self._params
        if self.trainable and params is None:
            if self.trainer is None:
                self.train()
            if self.trainer is None:
                raise RuntimeError(
                    f"controller {c.name!r} needs training; set "
                    f"train_episodes > 0 or call session.train(episodes)")
            params = self.trainer.params
        return controller_factory(c.name)(c, self.pipe, params)

    def serve(self, *, on_step=None) -> dict:
        """Run the control loop over the scenario horizon. ``on_step(env,
        cfg, info)`` is called after each adaptation interval."""
        env = self.build_env()
        if self.controller is None:
            self.controller = self.build_controller()
        controller = self.controller
        if hasattr(controller, "warmup"):
            # jit compile happens outside the timed loop, so decide_wall_s
            # and decision_times agree from the first decision on
            controller.warmup(env.observe())
        if hasattr(controller, "decision_times"):
            controller.decision_times = []
        # build_env() returns a freshly reset env — no second reset needed
        steps: dict[str, list] = {k: [] for k in _STEP_KEYS}
        rewards, configs, decide_walls = [], [], []
        wall0 = time.perf_counter()
        done = False
        with self._sanitize_scope():
            while not done:
                t0 = time.perf_counter()
                cfg = decide(controller, env)
                decide_walls.append(time.perf_counter() - t0)
                _, r, done, info = env.step(cfg)
                rewards.append(float(r))
                configs.append([list(cfg.z), list(cfg.f), list(cfg.b)])
                for k in _STEP_KEYS:
                    steps[k].append(float(info[k]))
                if on_step:
                    on_step(env, cfg, info)
        summary = env.drain() if hasattr(env, "drain") else {}
        if hasattr(env, "runtime"):
            summary["submitted"] = env.submitted
            summary["switches"] = env.runtime.switch_count
            summary["utilization"] = env.runtime.utilization()
            summary["virtual_now"] = env.runtime.now
        self._report = {
            "experiment": self.spec.to_dict(),
            # params injected via with_params() are not derivable from the
            # spec — flag it so nobody mistakes this report for spec-reproducible
            "external_params": self._params is not None,
            "rewards": rewards,
            "configs": configs,
            "decide_wall_s": decide_walls,
            "serve_wall_s": time.perf_counter() - wall0,
            "summary": {k: (v.tolist() if isinstance(v, np.ndarray) else v)
                        for k, v in summary.items()},
            **{k: v for k, v in steps.items()},
        }
        if hasattr(controller, "decision_times"):
            self._report["decision_times"] = list(controller.decision_times)
            self._report["decision_time_total"] = float(
                np.sum(controller.decision_times))
        return self._report

    # -------------------------------------------------------------- report --

    def report(self) -> dict:
        """JSON-safe results of the last serve (run on demand if it has not
        happened yet; serve trains lazily when the controller needs it)."""
        if self._report is None:
            self.serve()
        return self._report


def run_experiment(spec: ExperimentSpec | dict | str, *, log=None,
                   on_step=None) -> dict:
    """One-shot convenience: Session.from_spec -> train -> serve -> report."""
    sess = Session.from_spec(spec)
    sess.train(log=log)
    sess.serve(on_step=on_step)
    return sess.report()


class FleetSession:
    """The Session facade for a multi-tenant fleet: builds every tenant's
    pipeline on the shared cluster, trains learned tenant controllers via
    per-tenant sub-Sessions, then serves all tenants on one shared event
    loop (``serving.fleet.FleetRuntime``). Fully seeded from the spec."""

    def __init__(self, spec: FleetSpec):
        self.spec = spec
        self.fleet = None
        self._params: dict[str, object] = {}    # tenant name -> trained params
        self._forecasters: dict[str, object] = {}  # tenant name -> forecaster
        self._report: dict | None = None

    @classmethod
    def from_spec(cls, spec: FleetSpec | dict | str) -> FleetSession:
        if isinstance(spec, str):
            spec = json.loads(spec)
        if isinstance(spec, dict):
            spec = FleetSpec.from_dict(spec)
        return cls(spec)

    def train(self, *, log=None) -> FleetSession:
        """PPO-train every learned tenant controller on its own pipeline
        view (no-op for baseline tenants)."""
        for t in self.spec.tenants:
            if (t.controller.name in _TRAINABLE
                    and t.controller.train_episodes > 0
                    and t.name not in self._params):
                sub = Session(ExperimentSpec(
                    pipeline=self.spec.tenant_pipeline(t),
                    scenario=t.scenario, controller=t.controller,
                    seq_len=self.spec.seq_len))
                sub.train(log=log)
                self._params[t.name] = sub.trainer.params
        return self

    def build_fleet(self, *, horizon: int | None = None):
        from repro.serving.fleet import build_fleet
        entries = []
        for t in self.spec.tenants:
            pipe = self.spec.tenant_pipeline(t).build()
            controller = controller_factory(t.controller.name)(
                t.controller, pipe, self._params.get(t.name))
            if t.scenario.predictor and t.name not in self._forecasters:
                # train the tenant's named forecaster on its own arrival
                # family (cached, so repeat build_fleet calls reuse it)
                sub = Session(ExperimentSpec(
                    pipeline=self.spec.tenant_pipeline(t),
                    scenario=t.scenario, controller=t.controller,
                    seq_len=self.spec.seq_len))
                self._forecasters[t.name] = sub.build_forecaster()
            entries.append({"name": t.name, "pipe": pipe,
                            "arrivals": t.scenario.build_arrivals(),
                            "controller": controller,
                            "priority": t.priority, "slo_p99": t.slo_p99,
                            "forecaster": self._forecasters.get(t.name)})
        return build_fleet(entries,
                           admission_limit=self.spec.admission_limit,
                           min_share=self.spec.min_share,
                           horizon=horizon or self.spec.horizon,
                           seq_len=self.spec.seq_len)

    def serve(self, *, horizon: int | None = None, on_step=None) -> dict:
        """Run the fleet control loop: one ``step_interval`` per adaptation
        interval over the horizon, then drain. ``on_step(fleet, interval)``
        is called after each interval with the per-tenant results."""
        from repro.core.mdp import ADAPTATION_INTERVAL
        self.train()
        horizon = int(horizon or self.spec.horizon)
        self.fleet = self.build_fleet(horizon=horizon)
        n_steps = max(1, horizon // ADAPTATION_INTERVAL)
        rewards: dict[str, list[float]] = {t.name: []
                                           for t in self.spec.tenants}
        sheds: dict[str, list[int]] = {t.name: [] for t in self.spec.tenants}
        wall0 = time.perf_counter()
        for _ in range(n_steps):
            interval = self.fleet.step_interval()
            for name, info in interval.items():
                rewards[name].append(float(info["reward"]))
                sheds[name].append(int(info["shed"]))
            if on_step:
                on_step(self.fleet, interval)
        self.fleet.drain()
        wall = time.perf_counter() - wall0
        summary = self.fleet.summary()
        summary["fleet"]["events_per_s"] = (self.fleet.loop.events
                                            / max(wall, 1e-9))
        self._report = {
            "fleet_spec": self.spec.to_dict(),
            "serve_wall_s": wall,
            "rewards": rewards,
            "shed_per_interval": sheds,
            "summary": {k: (v.tolist() if isinstance(v, np.ndarray) else v)
                        for k, v in summary.items()},
        }
        return self._report

    def report(self) -> dict:
        if self._report is None:
            self.serve()
        return self._report
