"""Synthetic token data pipeline.

LM batches use a Zipf-distributed vocabulary with a deterministic structure
(a repeating Markov chain per sequence) so that a ~100M model trained for a
few hundred steps shows a real, measurable loss drop — pure-uniform tokens
have irreducible loss = log V and show nothing.
"""
from __future__ import annotations

import numpy as np


def _zipf_probs(vocab: int, a: float = 1.2) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return p / p.sum()


def synthetic_lm_batches(*, vocab: int, seq_len: int, batch: int, seed: int = 0,
                         n_states: int = 64):
    """Infinite generator of {"tokens", "labels"} batches.

    Tokens follow a random deterministic automaton over ``n_states`` states
    emitting Zipf-ranked symbols — learnable structure with entropy well
    below log(V).
    """
    rng = np.random.default_rng(seed)
    emit = rng.choice(vocab, size=(n_states, 8), p=_zipf_probs(vocab))
    trans = rng.integers(0, n_states, size=(n_states, 8))
    while True:
        toks = np.zeros((batch, seq_len + 1), dtype=np.int32)
        state = rng.integers(0, n_states, size=batch)
        for t in range(seq_len + 1):
            e = rng.integers(0, 8, size=batch)
            toks[:, t] = emit[state, e]
            state = trans[state, e]
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


def synthetic_requests(n: int, *, vocab: int = 512, seq_len: int = 32,
                       seed: int = 0):
    """Request token prompts for the serving examples."""
    rng = np.random.default_rng(seed)
    from repro.serving.batcher import Request
    return [Request(rid=i, tokens=rng.integers(1, vocab, size=seq_len).astype(np.int32))
            for i in range(n)]
