from repro.data.tokens import synthetic_lm_batches, synthetic_requests
