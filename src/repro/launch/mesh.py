"""Production mesh construction. A FUNCTION (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e); multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
