import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh with 512 placeholder host devices, and extract the
memory / cost / collective figures that feed §Dry-run and §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out D]

The XLA_FLAGS line above MUST run before any jax import (device count locks
on first jax init) — keep these the first two statements of the module.
"""

import argparse
import json
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import ARCHS
from repro.distributed import sharding as shd
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.models import api, steps
from repro.models.config import INPUT_SHAPES
from repro.train import adamw_init

# --------------------------------------------------------- hw constants ----
PEAK_FLOPS = 197e12     # bf16 / chip (v5e)
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / ICI link

SKIPS = {
    # enc-dec with 448 target positions has no 500k-decode regime (DESIGN.md)
    ("whisper-small", "long_500k"): "enc-dec: no 500k decode regime",
}

def model_flops(cfg, shape) -> float:
    """6·N_active·D for train, 2·N_active·D for inference FLOPs/step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * 1 * shape.global_batch           # decode: one token


def build_inputs(cfg, shape, mesh, *, multi_pod: bool):
    """(abstract args, in_shardings, step_fn) for one (arch, shape)."""
    bs = steps.batch_specs(cfg, shape)
    bsh = shd.batch_shardings(cfg, shape, mesh, multi_pod=multi_pod)
    psh = shd.param_shardings(cfg, mesh, multi_pod=multi_pod, kind=shape.kind)
    params_shape = jax.eval_shape(lambda k: api.init_model(k, cfg),
                                  jax.random.PRNGKey(0))
    if shape.kind == "train":
        shard_h = shd.residual_constraint(cfg, shape, mesh, multi_pod=multi_pod)
        # Gradient accumulation (make_train_step(microbatch=...)) was
        # measured OFF here: under GSPMD the grad all-reduce fires once per
        # microbatch (deepseek coll 24 -> 86 s at mb=4) and FSDP weights are
        # re-gathered per microbatch (llama4 coll 13 -> 35 s). It remains a
        # launcher option for memory-constrained real runs.
        mb = None
        step = steps.make_train_step(cfg, shard_h=shard_h, microbatch=mb)
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        zsh = shd.opt_shardings(cfg, mesh, multi_pod=multi_pod)  # ZeRO-1
        osh = {"m": zsh, "v": zsh, "step": NamedSharding(mesh, P())}
        # params and opt state are updated in place on real hardware
        return (params_shape, opt_shape, bs), (psh, osh, bsh), step, (0, 1)
    if shape.kind == "prefill":
        shard_h = shd.residual_constraint(cfg, shape, mesh, multi_pod=multi_pod)
        step = steps.make_prefill_step(cfg, shard_h=shard_h)
        return (params_shape, bs), (psh, bsh), step, ()
    cache = steps.cache_specs(cfg, shape)
    csh = shd.cache_shardings(cfg, shape, mesh, multi_pod=multi_pod)
    step = steps.make_serve_step(cfg, shape)
    # the KV/state cache is donated: decode updates it in place
    return (params_shape, bs, cache), (psh, bsh, csh), step, (2,)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True) -> dict:
    shape = INPUT_SHAPES[shape_name]
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "SKIP", "reason": SKIPS[(arch, shape_name)]}
    cfg = ARCHS[arch].replace(dtype="bfloat16")
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v

    args, in_sh, step, donate = build_inputs(cfg, shape, mesh,
                                             multi_pod=multi_pod)
    t0 = time.time()
    with compat.use_mesh(mesh):
        lowered = jax.jit(step, in_shardings=in_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    # trip-count-aware costs (XLA's cost_analysis counts scan bodies once)
    hc = hlo_cost.analyze(hlo)
    coll = hc["collectives"]
    coll_bytes = hc["collective_bytes"]

    flops_dev = float(hc["flops"])
    bytes_dev = float(hc["bytes"])
    mf = model_flops(cfg, shape)
    terms = {
        # per-chip seconds (cost_analysis is the per-device SPMD program)
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_bytes / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "OK",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_bytes,
        "collectives": coll,
        "xla_cost_analysis": {"flops_body_once": float(ca.get("flops", 0.0)),
                              "bytes_body_once": float(ca.get("bytes accessed", 0.0))},
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes": (ma.argument_size_in_bytes + ma.temp_size_in_bytes),
        },
        "roofline": {**{k: float(v) for k, v in terms.items()},
                     "dominant": dominant},
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops_dev if flops_dev else 0.0,
    }
    if verbose:
        mem_gb = rec["memory"]["peak_bytes"] / 1e9
        print(f"{arch:26s} {shape_name:12s} {rec['mesh']:8s} "
              f"compile={t_compile:6.1f}s mem={mem_gb:7.2f}GB "
              f"comp={terms['compute_s']*1e3:8.2f}ms "
              f"mem_t={terms['memory_s']*1e3:8.2f}ms "
              f"coll={terms['collective_s']*1e3:8.2f}ms -> {dominant}"
              f" useful={rec['useful_flops_ratio']:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list(ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"{tag}: cached")
                    continue
                try:
                    rec = dryrun_one(arch, shape, multi_pod=mp)
                except Exception as e:  # noqa: BLE001 — report and continue
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "FAIL", "error": str(e)[:2000]}
                    failures.append(tag)
                    print(f"{tag}: FAIL {str(e)[:200]}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
