"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 50 \
        [--smoke] [--batch 8] [--seq-len 256] [--microbatch 2]

On the dev box this runs the REAL train step (reduced config with --smoke);
on a TPU slice the same code path shards over the production mesh — the
only difference is the mesh construction and in_shardings, which are the
exact objects the multi-pod dry-run compiles (launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data.tokens import synthetic_lm_batches
from repro.models import api, steps
from repro.train import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (default on CPU dev boxes)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    args = ap.parse_args()

    cfg = ARCHS[args.arch].smoke() if args.smoke else ARCHS[args.arch]
    print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params on "
          f"{jax.device_count()} device(s)")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    train = jax.jit(steps.make_train_step(cfg, lr=args.lr,
                                          microbatch=args.microbatch),
                    donate_argnums=(0, 1))
    data = synthetic_lm_batches(vocab=cfg.vocab, seq_len=args.seq_len,
                                batch=args.batch, seed=0)
    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, metrics = train(params, opt, batch)
        if step % 10 == 0 or step == 1:
            print(f"step {step:4d} loss={float(metrics['loss']):8.4f} "
                  f"grad_norm={float(metrics['grad_norm']):7.3f} "
                  f"({(time.time() - t0) / step:.2f}s/step)")
    print("done")


if __name__ == "__main__":
    main()
