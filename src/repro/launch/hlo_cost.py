"""Trip-count-aware cost analysis over optimised HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so scanned
layer stacks (lax.scan over 95 deepseek layers) under-report FLOPs/bytes by
~L x. This module re-derives the three roofline inputs from
``compiled.as_text()`` with loop scaling:

  * builds a global def-map (instruction name -> result shape) because the
    optimised HLO references operands by name without inline shapes,
  * builds the computation call graph (fusions, calls, while bodies,
    conditional branches),
  * recovers scan trip counts from the loop-condition comparison constant,
  * counts dot FLOPs exactly (2 * prod(result) * prod(lhs contracting dims)),
    elementwise/reduce FLOPs approximately (prod(result)),
  * counts bytes as operand+result bytes per instruction, fusion internals
    excluded (HloCostAnalysis' "bytes accessed" convention),
  * sums collective result bytes per op kind, scaled by enclosing loops.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
                "s4": 1, "u4": 1, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[a-z][a-z0-9]*\[[\d,]*\]\S*)\s+([\w\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n"\s*:\s*"(\d+)"')
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "select",
    "compare", "and", "or", "xor", "not", "convert", "floor", "ceil", "sign",
    "cosine", "sine", "logistic", "expm1", "log1p", "atan2", "remainder",
    "reduce", "reduce-window", "clamp", "round-nearest-afz",
    "round-nearest-even", "cbrt", "erf",
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dims(s: str):
    return [int(d) for d in s.split(",") if d] if s else []


def _shapes_info(text: str):
    """All shape literals in ``text`` -> (bytes, elems)."""
    b = e = 0
    for dt, d in _SHAPE_RE.findall(text):
        n = 1
        for x in _dims(d):
            n *= x
        e += n
        b += n * _DTYPE_BYTES.get(dt, 4)
    return b, e


@dataclass
class Instr:
    name: str
    opcode: str
    line: str
    shape_str: str
    operands: list
    calls: list


def _parse(hlo: str):
    """-> (computations: name -> [Instr], defs: name -> shape_str, entry)."""
    comps: dict[str, list[Instr]] = {}
    defs: dict[str, str] = {}
    entry = None
    cur = None
    pending = None          # multi-line computation header in progress
    for raw in hlo.splitlines():
        line = raw.strip()
        if pending is not None:
            if line.endswith("{"):
                cur = pending
                pending = None
            continue
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m and "=" not in line.split("(")[0]:
                name = m.group(1)
                comps[name] = []
                if line.startswith("ENTRY"):
                    entry = name
                if line.endswith("{"):
                    cur = name
                else:
                    pending = name
                continue
        if line.startswith("}"):
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m or cur is None:
            continue
        name, shape_str, opcode = m.groups()
        defs[name] = shape_str
        # operands inside the eventual parens after the opcode
        p0 = line.find(opcode + "(", m.end(0) - len(opcode) - 1)
        p0 = line.find("(", line.find(opcode, m.end(3) - len(opcode) - 2))
        operands: list[str] = []
        if p0 > 0:
            depth = 0
            for i in range(p0, len(line)):
                if line[i] == "(":
                    depth += 1
                elif line[i] == ")":
                    depth -= 1
                    if depth == 0:
                        operands = _OPERAND_RE.findall(line[p0:i + 1])
                        break
        calls = []
        for kw in ("calls", "to_apply", "body", "condition"):
            cm = re.search(kw + r"=%?([\w\.\-]+)", line)
            if cm:
                calls.append((kw, cm.group(1)))
        bm = re.search(r"branch_computations=\{([^}]*)\}", line)
        if bm:
            calls += [("branch", c.strip().lstrip("%"))
                      for c in bm.group(1).split(",")]
        comps[cur].append(Instr(name, opcode, line, shape_str, operands, calls))
    return comps, defs, entry


def analyze(hlo: str) -> dict:
    comps, defs, entry = _parse(hlo)
    memo: dict[str, dict] = {}

    def operand_bytes(ins: Instr) -> int:
        return sum(_shapes_info(defs.get(o, ""))[0] for o in ins.operands)

    def dot_flops(ins: Instr) -> float:
        _, res_elems = _shapes_info(ins.shape_str)
        k = 1
        cm = _CONTRACT_RE.search(ins.line)
        if cm and ins.operands:
            lhs_shape = defs.get(ins.operands[0], "")
            m = _SHAPE_RE.search(lhs_shape)
            if m:
                lhs_dims = _dims(m.group(2))
                for ci in _dims(cm.group(1)):
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
        return 2.0 * res_elems * k

    def fusion_io_bytes(ins: Instr, body: str) -> float:
        """Boundary bytes of a fusion, at TPU semantics: an operand whose
        only body uses are dynamic-slice/gather (or the in-place target of a
        dynamic-update-slice) is charged at slice granularity, not the full
        buffer; a DUS root writes only the update region."""
        instrs = comps.get(body, [])
        by_name = {bi.name: bi for bi in instrs}
        param_of = {}
        for bi in instrs:
            if bi.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", bi.line)
                if m:
                    param_of[int(m.group(1))] = bi.name
        uses: dict[str, list[Instr]] = {}
        for bi in instrs:
            for o in bi.operands:
                uses.setdefault(o, []).append(bi)

        _PASS = ("convert", "bitcast", "reshape", "copy")

        def effective_uses(name: str, depth: int = 0) -> list:
            """Uses of ``name``, looking through dtype/layout-only ops (XLA
            CPU hoists attention's f32 convert into cache-update fusions —
            on TPU the buffer is updated in place in its own dtype)."""
            out = []
            for u in uses.get(name, []):
                if u.opcode in _PASS and depth < 4:
                    # the deeper tuples carry the name the final consumer
                    # actually reads, so DUS operand-0 checks line up
                    out += effective_uses(u.name, depth + 1)
                else:
                    out.append((u, name))
            return out

        def dus_update_bytes(u: Instr) -> float:
            """Update-region bytes of a DUS (operand 1) or scatter (operand 2)."""
            idx = 2 if u.opcode == "scatter" else 1
            if len(u.operands) > idx:
                return _shapes_info(defs.get(u.operands[idx], ""))[0]
            return 0.0

        def unwrap(name: str, depth: int = 0):
            bi = by_name.get(name)
            while bi is not None and bi.opcode in _PASS and bi.operands \
                    and depth < 4:
                bi = by_name.get(bi.operands[0])
                depth += 1
            return bi

        total = 0.0
        for i, op_name in enumerate(ins.operands):
            full = _shapes_info(defs.get(op_name, ""))[0]
            pname = param_of.get(i)
            us = effective_uses(pname) if pname else []
            slicey = us and all(
                u.opcode in ("dynamic-slice", "gather")
                or (u.opcode in ("dynamic-update-slice", "scatter")
                    and u.operands and u.operands[0] == via)
                for u, via in us)
            if slicey:
                sliced = 0.0
                for u, _ in us:
                    if u.opcode in ("dynamic-update-slice", "scatter"):
                        sliced += dus_update_bytes(u)
                    else:
                        sliced += _shapes_info(u.shape_str)[0]
                total += min(full, sliced)
            else:
                total += full
        # result write: a DUS root (possibly behind converts) updates in place
        root = next((bi for bi in instrs if bi.line.startswith("ROOT")
                     or " ROOT " in bi.line), None)
        real_root = unwrap(root.name) if root is not None else None
        if real_root is not None and real_root.opcode in (
                "dynamic-update-slice", "scatter"):
            total += dus_update_bytes(real_root)
        else:
            total += _shapes_info(ins.shape_str)[0]
        return total

    def trip_count(ins: Instr, cond: str | None) -> int:
        m = _TRIP_RE.search(ins.line)         # backend_config known_trip_count
        if m:
            return int(m.group(1))
        consts = []
        for ci in comps.get(cond or "", []):
            consts += [int(c) for c in _CONST_RE.findall(ci.line)]
        return max(consts) if consts else 1

    def comp_cost(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}}
        flops = 0.0
        nbytes = 0.0
        coll: dict[str, dict] = {}

        def merge(sub, mult=1):
            nonlocal flops, nbytes
            flops += sub["flops"] * mult
            for k, v in sub["coll"].items():
                r = coll.setdefault(k, {"count": 0, "bytes": 0})
                r["count"] += v["count"] * mult
                r["bytes"] += v["bytes"] * mult

        for ins in comps[name]:
            res_b, res_e = _shapes_info(ins.shape_str)
            if ins.opcode == "dot":
                flops += dot_flops(ins)
                nbytes += res_b + operand_bytes(ins)
            elif ins.opcode == "fusion":
                body = next((c for _, c in ins.calls), None)
                nbytes += fusion_io_bytes(ins, body) if body else (
                    res_b + operand_bytes(ins))
                for _, c in ins.calls:
                    sub = comp_cost(c, stack + (name,))
                    merge(sub)              # flops/collectives from inside
            elif ins.opcode == "while":
                body = next((c for kw, c in ins.calls if kw == "body"), None)
                cond = next((c for kw, c in ins.calls if kw == "condition"), None)
                trips = trip_count(ins, cond)
                if body:
                    sub = comp_cost(body, stack + (name,))
                    merge(sub, trips)
                    nbytes += sub["bytes"] * trips
                nbytes += res_b
            elif ins.opcode in ("parameter", "constant", "get-tuple-element",
                                "tuple", "bitcast", "reshape", "broadcast",
                                "iota"):
                pass                        # no real traffic (fused/aliased on TPU)
            elif ins.opcode == "dynamic-update-slice":
                # with buffer donation the big operand is updated in place:
                # traffic = the update slice read + written region
                upd = _shapes_info(defs.get(ins.operands[1], ""))[0] \
                    if len(ins.operands) > 1 else 0
                nbytes += 2 * upd
            elif ins.opcode in ("dynamic-slice", "gather"):
                # reads only the sliced/gathered region, writes the result
                nbytes += 2 * res_b
            elif ins.opcode == "scatter":
                upd = _shapes_info(defs.get(ins.operands[2], ""))[0] \
                    if len(ins.operands) > 2 else res_b
                nbytes += 2 * upd
            else:
                if ins.opcode in _ELEMENTWISE:
                    flops += res_e
                nbytes += res_b + operand_bytes(ins)
                for _, c in ins.calls:
                    sub = comp_cost(c, stack + (name,))
                    merge(sub)
                    nbytes += sub["bytes"]
            for cop in COLLECTIVES:
                if ins.opcode == cop:
                    r = coll.setdefault(cop, {"count": 0, "bytes": 0})
                    r["count"] += 1
                    r["bytes"] += res_b
        cost = {"flops": flops, "bytes": nbytes, "coll": coll}
        memo[name] = cost
        return cost

    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n]))
    c = comp_cost(entry)
    coll_bytes = sum(v["bytes"] for v in c["coll"].values())
    return {"flops": c["flops"], "bytes": c["bytes"],
            "collectives": c["coll"], "collective_bytes": coll_bytes,
            "entry": entry}
