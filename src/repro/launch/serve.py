"""Serving launcher: batched single-token decode against a KV cache — the
data plane the OPD controller manages.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        [--batch 4] [--context 128] [--tokens 32]

Runs prefill once to populate the cache, then streams decode steps. On TPU
the same serve_step is what launch/dryrun.py compiles for the decode_32k /
long_500k shapes of the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    args = ap.parse_args()

    cfg = ARCHS[args.arch].smoke() if args.smoke else ARCHS[args.arch]
    if cfg.enc_len:
        raise SystemExit("use whisper decode via models.api directly; the "
                         "serve launcher drives decoder-only archs")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B = args.batch

    cache = api.init_cache(cfg, B, args.context)
    decode = jax.jit(lambda p, b, c: api.decode_step(p, b, c, cfg),
                     donate_argnums=(2,))

    tok = jnp.asarray(rng.integers(1, cfg.vocab, (B, 1)), dtype=jnp.int32)
    out_tokens = []
    t0 = time.time()
    for i in range(args.tokens):
        logits, cache = decode(params, {"tokens": tok}, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok)[:, 0])
        if i == 0:
            print(f"first token (incl. compile): {time.time() - t0:.2f}s")
    dt = time.time() - t0
    toks = B * args.tokens
    print(f"decoded {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, batch {B})")
    print("sample:", np.stack(out_tokens, 1)[0][:16])


if __name__ == "__main__":
    main()
