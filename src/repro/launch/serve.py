"""Serving launcher: batched single-token decode against a KV cache — the
data plane the OPD controller manages — plus the event-driven pipeline mode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        [--batch 4] [--context 128] [--tokens 32]

    PYTHONPATH=src python -m repro.launch.serve --pipeline \
        [--scenario bursty] [--horizon 120] [--policy greedy] [--seed 3] \
        [--cluster edge-hetero-3]

    PYTHONPATH=src python -m repro.launch.serve \
        --fleet fleet-3tenant-hetero [--horizon 120]

Single-arch mode runs prefill once to populate the cache, then streams
decode steps; on TPU the same serve_step is what launch/dryrun.py compiles
for the decode_32k / long_500k shapes of the production mesh. ``--pipeline``
instead serves an arrival scenario through the event-driven runtime with any
registered controller in the loop (``--policy opd`` trains the agent first),
printing per-interval telemetry. ``--fleet`` serves a registered multi-tenant
fleet (N pipelines on one shared cluster and event loop) and prints the
per-tenant shed / latency summary. Everything is built from ``repro.api``
specs, so the run is reproducible from its seeds.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import api


def _ms(v) -> str:
    """Milliseconds formatter, null-safe (summary emits None when nothing
    completed)."""
    return "n/a" if v is None else f"{v * 1e3:.0f}ms"


def run_pipeline(args):
    from repro import api

    pipeline = api.get_pipeline("serve2")
    if args.cluster:
        # place the pipeline on a registered (possibly heterogeneous)
        # cluster topology instead of the homogeneous scalar pool
        pipeline = api.replace(pipeline, cluster=api.get_cluster(args.cluster))
    exp = api.ExperimentSpec(
        pipeline=pipeline,
        scenario=api.replace(api.get_scenario(args.scenario), rate=args.rate,
                             seed=args.seed, horizon=args.horizon),
        controller=api.replace(api.get_controller(args.policy),
                               seed=args.seed))
    sess = api.Session.from_spec(exp)
    sess.train(log=print)

    def show(env, cfg, info):
        line = (f"t={env.runtime.now:5.0f}s z={cfg.z} f={cfg.f} b={cfg.b} "
                f"demand={info['demand']:5.1f}/s served={info['processed']:4d} "
                f"p95={info['p95'] * 1e3:7.1f}ms backlog={info['backlog']}")
        if args.cluster:
            line += (" nodes=" + "/".join(f"{u:.2f}"
                                          for u in info["node_utilization"])
                     + f" migrations={info['migrations']}")
        print(line)

    s = sess.serve(on_step=show)["summary"]
    print(f"served {s['served']} requests ({s['throughput_rps']:.1f} req/s) "
          f"p50={_ms(s['p50'])} p95={_ms(s['p95'])} p99={_ms(s['p99'])}")
    if args.cluster:
        print(f"cluster {args.cluster}: "
              f"{s['migrations']} replica migrations, node utilization "
              + " ".join(f"{u:.2f}" for u in s.get("node_utilization", [])))


def run_fleet(args):
    from repro import api

    spec = api.get_fleet(args.fleet)
    sess = api.FleetSession.from_spec(spec)

    def show(fleet, interval):
        now = fleet.loop.now
        for name, info in interval.items():
            print(f"t={now:5.0f}s {name:<12} demand={info['demand']:5.1f}/s "
                  f"served={info['processed']:4d} shed={info['shed']:3d} "
                  f"p95={_ms(info['p95'] if info['p95'] == info['p95'] else None)}"
                  f" backlog={info['backlog']}")

    rep = sess.serve(horizon=args.horizon, on_step=show)
    s = rep["summary"]
    for name, t in s["tenants"].items():
        line = (f"tenant {name:<12} prio={t['priority']} "
                f"share={t['share']:.2f} offered={t['arrived']:6d} "
                f"served={t['served']:6d} shed={t['shed']:5d} "
                f"({t['shed_rate'] * 100:.1f}%) p50={_ms(t['p50'])} "
                f"p95={_ms(t['p95'])} p99={_ms(t['p99'])}")
        if "slo_p99" in t:
            line += (f" slo_p99={_ms(t['slo_p99'])} "
                     f"{'MET' if t['slo_p99_met'] else 'MISSED'}")
        print(line)
    f = s["fleet"]
    print(f"fleet {spec.name}: {f['tenants']} tenants, "
          f"{f['served']}/{f['offered']} served "
          f"(shed {f['shed']}, {f['shed_rate'] * 100:.1f}%), "
          f"{f['events']} events ({f['events_per_s']:.0f}/s), "
          f"{f['reallocations']} reallocations")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--pipeline", action="store_true",
                    help="serve an arrival scenario through the event-driven "
                         "pipeline runtime instead of single-arch decode")
    from repro.api import (list_clusters, list_controllers, list_fleets,
                           list_scenarios)
    ap.add_argument("--scenario", default="bursty", choices=list_scenarios())
    ap.add_argument("--policy", default="greedy", choices=list_controllers())
    ap.add_argument("--cluster", default=None, choices=list_clusters(),
                    help="place the pipeline on a registered cluster "
                         "topology (default: homogeneous scalar pool)")
    ap.add_argument("--fleet", default=None, choices=list_fleets(),
                    help="serve a registered multi-tenant fleet (N pipelines "
                         "on one shared cluster and event loop)")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--rate", type=float, default=25.0)
    args = ap.parse_args()

    if args.fleet:
        return run_fleet(args)
    if args.pipeline:
        return run_pipeline(args)

    cfg = ARCHS[args.arch].smoke() if args.smoke else ARCHS[args.arch]
    if cfg.enc_len:
        raise SystemExit("use whisper decode via models.api directly; the "
                         "serve launcher drives decoder-only archs")
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B = args.batch

    cache = api.init_cache(cfg, B, args.context)
    decode = jax.jit(lambda p, b, c: api.decode_step(p, b, c, cfg),
                     donate_argnums=(2,))

    tok = jnp.asarray(rng.integers(1, cfg.vocab, (B, 1)), dtype=jnp.int32)
    out_tokens = []
    t0 = time.time()
    for i in range(args.tokens):
        logits, cache = decode(params, {"tokens": tok}, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok)[:, 0])
        if i == 0:
            print(f"first token (incl. compile): {time.time() - t0:.2f}s")
    dt = time.time() - t0
    toks = B * args.tokens
    print(f"decoded {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, batch {B})")
    print("sample:", np.stack(out_tokens, 1)[0][:16])


if __name__ == "__main__":
    main()
