from repro.train.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.train.loss import lm_loss, chunked_lm_head_loss
