"""Cross-entropy LM loss with label masking and MoE aux-loss folding.

``chunked_lm_head_loss`` fuses the lm_head projection into the loss, one
sequence-chunk at a time under remat: the full [B, S, V] logits tensor
(13-33 GB/device at S=4k for 50k-128k vocabs) never materialises — peak is
one [B, chunk, V] block, recomputed during backward.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import linear


def lm_loss(logits, labels, *, mask=None, lb_loss=None, lb_coeff: float = 0.01):
    """logits [B, S, V]; labels [B, S] (-100 = ignore); returns (loss, metrics)."""
    valid = labels >= 0
    if mask is not None:
        valid = valid & mask
    safe_labels = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(jnp.where(valid, nll, 0.0)) / denom
    total = loss
    if lb_loss is not None:
        total = total + lb_coeff * lb_loss
    return total, {"ce_loss": loss, "n_tokens": denom}


def chunked_lm_head_loss(head, h, labels, *, lb_loss=None, lb_coeff: float = 0.01,
                         chunk: int = 512):
    """h [B, S, d] (post-final-norm), head = lm_head linear params,
    labels [B, S] (-100 = ignore) -> (loss, metrics). Sequence-chunked +
    remat so at most one [B, chunk, V] logits block is ever live."""
    B, S, d = h.shape
    if S <= chunk or S % chunk:
        return lm_loss(linear(head, h), labels, lb_loss=lb_loss,
                       lb_coeff=lb_coeff)
    nc = S // chunk
    h_c = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    y_c = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, inp):
        h_k, y_k = inp
        logits = linear(head, h_k)
        valid = y_k >= 0
        safe = jnp.where(valid, y_k, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return (acc[0] + jnp.sum(jnp.where(valid, nll, 0.0)),
                acc[1] + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (h_c, y_c))
    denom = jnp.maximum(cnt, 1)
    loss = tot / denom
    total = loss if lb_loss is None else loss + lb_coeff * lb_loss
    return total, {"ce_loss": loss, "n_tokens": denom}
