"""AdamW + gradient clipping, built from scratch (no optax in this env)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), dtype=jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state, *, lr: float = 3e-4, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.01):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (update + weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v,
                                            strict=True)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
