"""Top-k mixture-of-experts with capacity-bounded gather dispatch.

Dispatch is gather-based (per-expert top-C token selection) rather than the
GShard one-hot [T, E, C] tensor — the one-hot dispatch tensor for e.g.
granite-moe (T=4096, E=40, C=1024) would be 167M elements per device and
O(S·E·C·d) combine FLOPs; the gather form keeps only [E, C] indices and
[E, C, d] activations.

Distribution: under a mesh with a "model" axis the expert computation runs
inside shard_map — experts sharded over "model" (expert parallelism), batch
over "data"("pod","data") — because GSPMD's sharding propagation falls back
to full-batch replication for the batched scatter/gather pair this dispatch
needs (measured: +600 GB/device of all-gather/all-reduce per train step on
granite-moe). Inside shard_map every gather/scatter is shard-local and the
only communication is one f32 psum of the combined output over "model".

Expert count is physically padded to a multiple of 16 at init (router stays
at the logical E; padded experts are never routed to) so the expert dim
always divides the mesh "model" axis.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import ambient_mesh, shard_map
from repro.nn.linear import init_linear


def _phys_experts(n_experts: int) -> int:
    """Experts >= 16 are padded to a multiple of 16 (the mesh model-axis)."""
    return n_experts if n_experts < 16 else 16 * math.ceil(n_experts / 16)


def init_moe(key, dim: int, hidden: int, n_experts: int, *, dtype=jnp.float32):
    ks = jax.random.split(key, 4)

    def one_expert(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "wg": init_linear(k1, dim, hidden, dtype=dtype)["w"],
            "wu": init_linear(k2, dim, hidden, dtype=dtype)["w"],
            "wd": init_linear(k3, hidden, dim, dtype=dtype)["w"],
        }

    E_phys = _phys_experts(n_experts)
    experts = jax.vmap(one_expert)(jax.random.split(ks[0], E_phys))
    return {
        "router": init_linear(ks[1], dim, n_experts, dtype=jnp.float32),
        "experts": experts,   # each leaf [E_phys, ...]
    }


def _route(params, x, *, top_k: int, capacity_factor: float, E_phys: int):
    """Router + per-(row, expert) top-C dispatch plan.

    Returns gsel/tok_idx [B, E_phys, C], probs [B, S, E] and C. Scatter-free:
    gates are built with a one-hot sum over the k choices so GSPMD never sees
    a batched scatter here.
    """
    B, S, _ = x.shape
    E = params["router"]["w"].shape[1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"]["w"])                          # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)                          # [B,S,k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # gates[b, s, e] = weight if expert e chosen for token s else 0
    onehot = (top_e[..., None] == jnp.arange(E_phys)[None, None, None])  # [B,S,k,E+]
    gates = jnp.einsum("bsk,bske->bse", top_p,
                       onehot.astype(jnp.float32))                      # [B,S,E+]

    C = max(1, min(S, int(capacity_factor * S * top_k / E)))
    gsel, tok_idx = jax.lax.top_k(gates.transpose(0, 2, 1), C)          # [B,E+,C]
    return gsel, tok_idx, probs, C


def _expert_ffn(xe, wg, wu, wd):
    """xe [..., E, C, d] with stacked expert weights [E, d, f] / [E, f, d]."""
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, wg))
    h = h * jnp.einsum("becd,edf->becf", xe, wu)
    return jnp.einsum("becf,efd->becd", h, wd)


def _dispatch_compute_combine(x, gsel, tok_idx, wg, wu, wd):
    """Shard-local: gather tokens per expert, run the FFN, scatter-add back.
    x [B, S, d]; gsel/tok_idx [B, E, C] -> y [B, S, d] (f32)."""
    B, S, d = x.shape
    xe = jnp.take_along_axis(x[:, None], tok_idx[..., None], axis=2)    # [B,E,C,d]
    ye = _expert_ffn(xe, wg.astype(xe.dtype), wu.astype(xe.dtype),
                     wd.astype(xe.dtype))
    ye = ye * (gsel * (gsel > 0))[..., None].astype(ye.dtype)
    y = jnp.zeros((B, S, d), dtype=jnp.promote_types(ye.dtype, jnp.float32))
    bidx = jnp.arange(B)[:, None, None]
    y = y.at[jnp.broadcast_to(bidx, tok_idx.shape), tok_idx].add(ye)
    return y


def _aux(params, gsel, probs, E: int):
    """Switch-style load-balance loss + dropped-token fraction."""
    # fraction of routed slots per expert (padded experts contribute 0)
    B, S, _ = probs.shape
    used = (gsel > 0).astype(jnp.float32)                               # [B,E+,C]
    frac_tokens = used.sum(axis=(0, 2))[:E] / jnp.maximum(used.sum(), 1.0)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    # combined capacity vs demand: demanded slots = B*S*k, granted = used
    dropped = 1.0 - used.sum() / jnp.maximum(B * S * probs.shape[-1], 1)
    return {"lb_loss": lb_loss,
            "dropped_frac": jnp.clip(dropped, 0.0, 1.0)}


def moe(params, x, *, top_k: int, capacity_factor: float = 1.25,
        ep2d: bool = False):
    """x [B, S, d] -> (y [B, S, d], aux). Expert-parallel under a mesh.

    ``ep2d`` (decode path for 100B+ models): expert weights stay RESIDENT,
    two-axis sharded — E over "model", d_ff over "data" — and the tiny
    per-token activations are psum'd over both axes instead of re-gathering
    hundreds of GB of expert weights every decode step.
    """
    E = params["router"]["w"].shape[1]
    E_phys = params["experts"]["wg"].shape[0]
    gsel, tok_idx, probs, C = _route(params, x, top_k=top_k,
                                     capacity_factor=capacity_factor,
                                     E_phys=E_phys)
    mesh = ambient_mesh()
    ep = (mesh is not None and mesh.axis_names and
          "model" in mesh.axis_names and E_phys % mesh.shape["model"] == 0)
    w = params["experts"]
    if ep and ep2d and "data" in mesh.axis_names:

        def body2d(x_l, gsel_l, tok_l, wg_l, wu_l, wd_l):
            # x replicated (decode tokens are ~MBs); weights stay sharded:
            # wg/wu [E_loc, d, ff_loc], wd [E_loc, ff_loc, d]
            xe = jnp.take_along_axis(x_l[:, None], tok_l[..., None], axis=2)
            h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe,
                                       wg_l.astype(xe.dtype)))
            h = h * jnp.einsum("becd,edf->becf", xe, wu_l.astype(xe.dtype))
            ye = jnp.einsum("becf,efd->becd", h, wd_l.astype(xe.dtype))
            ye = ye * (gsel_l * (gsel_l > 0))[..., None].astype(ye.dtype)
            B, S, d = x_l.shape
            y = jnp.zeros((B, S, d), jnp.promote_types(ye.dtype, jnp.float32))
            bidx = jnp.arange(B)[:, None, None]
            y = y.at[jnp.broadcast_to(bidx, tok_l.shape), tok_l].add(ye)
            return jax.lax.psum(y, ("model", "data"))

        y = shard_map(
            body2d, mesh=mesh,
            in_specs=(P(None, None, None), P(None, "model", None),
                      P(None, "model", None), P("model", None, "data"),
                      P("model", None, "data"), P("model", "data", None)),
            out_specs=P(None, None, None),
        )(x, gsel, tok_idx, w["wg"], w["wu"], w["wd"])
    elif ep:
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        if x.shape[0] % n_dp != 0:
            dp = None          # e.g. batch=1 long-context decode: replicate B

        def body(x_l, gsel_l, tok_l, wg_l, wu_l, wd_l):
            y = _dispatch_compute_combine(x_l, gsel_l, tok_l, wg_l, wu_l, wd_l)
            return jax.lax.psum(y, "model")

        y = shard_map(
            body, mesh=mesh,
            in_specs=(P(dp, None, None), P(dp, "model", None),
                      P(dp, "model", None), P("model", None, None),
                      P("model", None, None), P("model", None, None)),
            out_specs=P(dp, None, None),
        )(x, gsel, tok_idx, w["wg"], w["wu"], w["wd"])
    else:
        y = _dispatch_compute_combine(x, gsel, tok_idx,
                                      w["wg"], w["wu"], w["wd"])
    return y.astype(x.dtype), _aux(params, gsel, probs, E)
