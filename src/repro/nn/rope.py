"""Rotary position embeddings."""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(head_dim: int, *, theta: float = 10000.0):
    """Inverse frequencies [head_dim//2], float32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x, positions, inv_freq):
    """x: [..., seq, heads, head_dim]; positions: [..., seq] int32."""
    dt = x.dtype
    # angles [..., seq, head_dim//2]
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    cos = jnp.cos(ang)[..., None, :]   # [..., seq, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)
