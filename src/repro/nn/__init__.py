"""Parameter-pytree neural-net library (no external deps beyond jax).

Every module is a pair of functions:
    init_<mod>(key, ...) -> params   (a dict pytree of jnp arrays)
    <mod>(params, x, ...) -> y

Layer stacks are built by vmapping init over a key batch and scanning apply.
"""
from repro.nn.linear import init_linear, linear, init_embedding, embedding
from repro.nn.norms import init_rmsnorm, rmsnorm, init_layernorm, layernorm
from repro.nn.rope import rope_frequencies, apply_rope
from repro.nn.mlp import init_mlp, mlp
from repro.nn.attention import (
    init_attention, attention_prefill, attention_decode, make_kv_cache,
)
from repro.nn.moe import init_moe, moe
from repro.nn.mamba2 import init_mamba2, mamba2_scan, mamba2_decode, make_mamba_state
from repro.nn.xlstm import (
    init_mlstm, mlstm_parallel, mlstm_chunkwise, mlstm_decode, make_mlstm_state,
    init_slstm, slstm_scan, slstm_decode, make_slstm_state,
)
from repro.nn.lstm import init_lstm, lstm_scan
from repro.nn.resnet import init_resblock, resblock, init_res_mlp, res_mlp
