"""Linear / embedding primitives."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_linear(key, in_dim: int, out_dim: int, *, bias: bool = False,
                dtype=jnp.float32, scale: float | None = None):
    """Lecun-normal weight [in, out] (+ optional zero bias)."""
    if scale is None:
        scale = 1.0 / (in_dim ** 0.5)
    w = (jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype=dtype)
    return p


def linear(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def init_embedding(key, vocab: int, dim: int, *, dtype=jnp.float32):
    e = (jax.random.normal(key, (vocab, dim), dtype=jnp.float32) * 0.02).astype(dtype)
    return {"e": e}


def embedding(params, tokens):
    return params["e"][tokens]
