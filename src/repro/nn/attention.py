"""Grouped-query attention: prefill (full-causal or sliding-window) and
single-token decode against a KV cache (contiguous or ring-buffer window).

Shapes:
    x           [B, S, d_model]
    q           [B, S, n_heads, head_dim]
    k/v         [B, S, n_kv, head_dim]
    cache k/v   [B, C, n_kv, head_dim]  (C = max context or window size)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import init_linear, linear
from repro.nn.rope import apply_rope, rope_frequencies


def init_attention(key, dim: int, n_heads: int, n_kv: int, head_dim: int,
                   *, dtype=jnp.float32, qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], dim, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": init_linear(ks[1], dim, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": init_linear(ks[2], dim, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_linear(ks[3], n_heads * head_dim, dim, dtype=dtype),
    }


def _qkv(params, x, n_heads: int, n_kv: int, head_dim: int):
    B, S, _ = x.shape
    q = linear(params["wq"], x).reshape(B, S, n_heads, head_dim)
    k = linear(params["wk"], x).reshape(B, S, n_kv, head_dim)
    v = linear(params["wv"], x).reshape(B, S, n_kv, head_dim)
    return q, k, v


def _sdpa(q, k, v, mask):
    """q [B,S,H,D]; k,v [B,T,Hkv,D]; mask [S,T] or [B,S,T] additive(-inf) bool=keep."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = q.reshape(B, S, Hkv, group, D)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (D ** 0.5)
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(mask, logits, neg)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


_NEG = -1e30            # finite -inf stand-in: keeps online-softmax grads NaN-free


def _sdpa_blocked(q, k, v, *, window=None, kv_chunk: int = 1024):
    """Causal GQA attention without the [S, S] tensor: a lax.scan over KV
    chunks carries the online-softmax state (m, l, acc) — the flash pattern
    in pure jnp, so long prefills stream O(S·chunk) instead of O(S²).
    q [B,S,H,D]; k,v [B,T,Hkv,D] -> [B,S,H,D]."""
    B, S, H, D = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    g = H // Hkv
    chunk = min(kv_chunk, T)
    assert T % chunk == 0, (T, chunk)
    nb = T // chunk
    # heads stay FLAT on the H axis (sharding-friendly — a [B,S,Hkv,g,D]
    # reshape would break the "model"-axis head sharding and every device
    # would compute all H heads); the small per-chunk KV block is repeated
    # to H instead (g-fold, ~MBs).
    qf = q.astype(jnp.float32) / (D ** 0.5)
    kc = jnp.moveaxis(k.reshape(B, nb, chunk, Hkv, D), 1, 0).astype(jnp.float32)
    vc = jnp.moveaxis(v.reshape(B, nb, chunk, Hkv, D), 1, 0).astype(jnp.float32)
    iq = jnp.arange(S)

    def body(carry, inp):
        m, lsum, acc = carry                    # [B,S,H] / [B,S,H] / [..,D]
        k_k, v_k, j0 = inp                      # [B,chunk,Hkv,D]
        kr = jnp.repeat(k_k, g, axis=2)         # [B,chunk,H,D]
        vr = jnp.repeat(v_k, g, axis=2)
        logits = jnp.einsum("bshd,bchd->bshc", qf, kr)        # [B,S,H,C]
        jk = j0 + jnp.arange(chunk)
        keep = jk[None, :] <= iq[:, None]                     # causal
        if window is not None:
            keep &= jk[None, :] > iq[:, None] - window
        logits = jnp.where(keep[None, :, None, :], logits, _NEG)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        scale = jnp.exp(m - m_new)
        lsum = lsum * scale + p.sum(axis=-1)
        acc = acc * scale[..., None] + jnp.einsum("bshc,bchd->bshd", p, vr)
        return (m_new, lsum, acc), None

    m0 = jnp.full((B, S, H), _NEG, jnp.float32)
    l0 = jnp.zeros((B, S, H), jnp.float32)
    a0 = jnp.zeros((B, S, H, D), jnp.float32)
    offs = jnp.arange(nb) * chunk
    (m, lsum, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, offs))
    out = acc / jnp.maximum(lsum, 1e-30)[..., None]
    return out.astype(q.dtype)


def attention_prefill(params, x, *, n_heads: int, n_kv: int, head_dim: int,
                      rope_theta: float | None = 10000.0, window: int | None = None,
                      positions=None, use_flash: bool = False,
                      blocked_threshold: int = 4096):
    """Causal self-attention over a full sequence. Returns (out, (k, v)).
    Sequences longer than ``blocked_threshold`` stream through the blocked
    online-softmax path (no [S, S] materialisation)."""
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, n_heads, n_kv, head_dim)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    if rope_theta is not None:
        inv = rope_frequencies(head_dim, theta=rope_theta)
        q = apply_rope(q, positions, inv)
        k = apply_rope(k, positions, inv)
    if use_flash:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window)
    elif S > blocked_threshold and S % 1024 == 0:
        out = _sdpa_blocked(q, k, v, window=window)
    else:
        idx = jnp.arange(S)
        mask = idx[None, :] <= idx[:, None]            # causal
        if window is not None:
            mask = mask & (idx[None, :] > idx[:, None] - window)
        out = _sdpa(q, k, v, mask[None, None, None, :, :])
    out = out.reshape(B, S, n_heads * head_dim)
    return linear(params["wo"], out), (k, v)


def make_kv_cache(batch: int, context: int, n_kv: int, head_dim: int, *, dtype=jnp.float32):
    sh = (batch, context, n_kv, head_dim)
    return {"k": jnp.zeros(sh, dtype=dtype), "v": jnp.zeros(sh, dtype=dtype),
            "pos": jnp.zeros((batch,), dtype=jnp.int32)}


def attention_decode(params, x, cache, *, n_heads: int, n_kv: int, head_dim: int,
                     rope_theta: float | None = 10000.0, ring: bool = False,
                     use_flash: bool = False):
    """One-token decode. x [B, 1, d]. cache entries [B, C, kv, hd].

    ``ring=True`` treats the cache as a sliding-window ring buffer (writes wrap);
    otherwise positions index the cache contiguously. Returns (out, new_cache).
    """
    B, S, _ = x.shape
    assert S == 1
    C = cache["k"].shape[1]
    pos = cache["pos"]                                   # [B]
    q, k, v = _qkv(params, x, n_heads, n_kv, head_dim)
    if rope_theta is not None:
        inv = rope_frequencies(head_dim, theta=rope_theta)
        q = apply_rope(q, pos[:, None], inv)
        k = apply_rope(k, pos[:, None], inv)
    slot = (pos % C) if ring else jnp.minimum(pos, C - 1)
    bidx = jnp.arange(B)
    # write in CACHE dtype: rope returns f32, and .at[].set would otherwise
    # promote the whole [B, C, kv, hd] buffer to f32 (2x HBM + converts)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))  # reprolint: ignore[RPL005] canonical decode-path KV slot write, not vmapped over the cache
    new_v = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))  # reprolint: ignore[RPL005] canonical decode-path KV slot write, not vmapped over the cache
    # valid slots: contiguous -> [0, pos]; ring -> min(pos+1, C) most recent
    n_valid = jnp.minimum(pos + 1, C)                    # [B]
    mask = jnp.arange(C)[None, :] < n_valid[:, None]     # [B, C]
    if use_flash:
        from repro.kernels import ops as kops
        out = kops.decode_attention(q, new_k, new_v, mask)
    else:
        out = _sdpa(q, new_k, new_v, mask[:, None, None, None, :])
    out = out.reshape(B, 1, n_heads * head_dim)
    new_cache = {"k": new_k, "v": new_v, "pos": pos + 1}
    return linear(params["wo"], out), new_cache


def init_cross_attention(key, dim: int, n_heads: int, head_dim: int, *, dtype=jnp.float32):
    return init_attention(key, dim, n_heads, n_heads, head_dim, dtype=dtype, qkv_bias=True)


def cross_attention(params, x, enc, *, n_heads: int, head_dim: int):
    """x [B,S,d] attends to encoder states enc [B,T,d] (no mask, no rope)."""
    B, S, _ = x.shape
    T = enc.shape[1]
    q = linear(params["wq"], x).reshape(B, S, n_heads, head_dim)
    k = linear(params["wk"], enc).reshape(B, T, n_heads, head_dim)
    v = linear(params["wv"], enc).reshape(B, T, n_heads, head_dim)
    mask = jnp.ones((1, 1, 1, S, T), dtype=bool)
    out = _sdpa(q, k, v, mask).reshape(B, S, n_heads * head_dim)
    return linear(params["wo"], out)
