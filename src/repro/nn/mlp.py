"""Feed-forward blocks: SwiGLU (llama-style) and GELU (whisper/gpt-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import init_linear, linear


def init_mlp(key, dim: int, hidden: int, *, kind: str = "swiglu", dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "wg": init_linear(ks[0], dim, hidden, dtype=dtype),
            "wu": init_linear(ks[1], dim, hidden, dtype=dtype),
            "wd": init_linear(ks[2], hidden, dim, dtype=dtype),
        }
    return {
        "w1": init_linear(ks[0], dim, hidden, bias=True, dtype=dtype),
        "w2": init_linear(ks[1], hidden, dim, bias=True, dtype=dtype),
    }


def mlp(params, x, *, kind: str = "swiglu"):
    if kind == "swiglu":
        g = linear(params["wg"], x)
        u = linear(params["wu"], x)
        return linear(params["wd"], jax.nn.silu(g) * u)
    h = jax.nn.gelu(linear(params["w1"], x))
    return linear(params["w2"], h)
