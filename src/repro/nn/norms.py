"""Normalisation layers."""
from __future__ import annotations

import jax.numpy as jnp


def init_rmsnorm(dim: int, *, dtype=jnp.float32):
    return {"g": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params, x, *, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * (1.0 / jnp.sqrt(var + eps))
    return (y * params["g"].astype(jnp.float32)).astype(dt)


def init_layernorm(dim: int, *, dtype=jnp.float32):
    return {"g": jnp.ones((dim,), dtype=dtype), "b": jnp.zeros((dim,), dtype=dtype)}


def layernorm(params, x, *, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) / jnp.sqrt(var + eps)
    return (y * params["g"].astype(jnp.float32) + params["b"].astype(jnp.float32)).astype(dt)
