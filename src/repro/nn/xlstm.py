"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelisable) and
sLSTM (scalar memory with recurrent gate connections, sequential scan).

mLSTM parallel (training/prefill) uses the stabilised attention-like form:
    F_t = cumsum log sigmoid(f̃);  D̃_ts = F_t - F_s + ĩ_s  (s <= t)
    m_t = max_s D̃_ts;   W_ts = exp(D̃_ts - m_t) (q_t·k_s/√d)
    y_t = Σ_s W_ts v_s / max(|Σ_s W_ts|, exp(-m_t))
mLSTM decode carries per-head matrix memory C [P, P], normaliser n [P],
stabiliser m (scalar).

sLSTM is a strict recurrence (gates see R h_{t-1}) -> lax.scan over time for
both train and decode, with exponential-gate stabilisation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import init_linear, linear
from repro.nn.norms import init_rmsnorm, rmsnorm

# ---------------------------------------------------------------- mLSTM ----


def init_mlstm(key, dim: int, n_heads: int, *, expand: int = 2, dtype=jnp.float32):
    d_inner = expand * dim
    ks = jax.random.split(key, 8)
    return {
        "up": init_linear(ks[0], dim, 2 * d_inner, dtype=dtype),   # -> (x, gate)
        "wq": init_linear(ks[1], d_inner, d_inner, dtype=dtype),
        "wk": init_linear(ks[2], d_inner, d_inner, dtype=dtype),
        "wv": init_linear(ks[3], d_inner, d_inner, dtype=dtype),
        "wi": init_linear(ks[4], d_inner, n_heads, bias=True, dtype=dtype),
        "wf": init_linear(ks[5], d_inner, n_heads, bias=True, dtype=dtype),
        "norm": init_rmsnorm(d_inner, dtype=dtype),
        "down": init_linear(ks[6], d_inner, dim, dtype=dtype),
    }


def _mlstm_qkvif(params, x, n_heads: int):
    B, S, _ = x.shape
    u = linear(params["up"], x)
    xi, gate = jnp.split(u, 2, axis=-1)
    d_inner = xi.shape[-1]
    P = d_inner // n_heads
    q = linear(params["wq"], xi).reshape(B, S, n_heads, P)
    k = linear(params["wk"], xi).reshape(B, S, n_heads, P) / (P ** 0.5)
    v = linear(params["wv"], xi).reshape(B, S, n_heads, P)
    i_pre = linear(params["wi"], xi).astype(jnp.float32)               # [B, S, H]
    f_pre = linear(params["wf"], xi).astype(jnp.float32)
    return q, k, v, i_pre, f_pre, gate, d_inner, P


def mlstm_parallel(params, x, *, n_heads: int, return_state: bool = False):
    """x [B, S, dim] -> y [B, S, dim] (quadratic parallel form).
    With return_state, also returns the recurrent (C, n, m) state after S
    steps (equivalent to running mlstm_decode S times)."""
    B, S, dim = x.shape
    q, k, v, i_pre, f_pre, gate, d_inner, P = _mlstm_qkvif(params, x, n_heads)
    logf = jax.nn.log_sigmoid(f_pre)                                   # [B, S, H]
    F = jnp.cumsum(logf, axis=1)
    dmat = F[:, :, None, :] - F[:, None, :, :] + i_pre[:, None, :, :]  # [B,t,s,H]
    causal = jnp.tril(jnp.ones((S, S), dtype=bool))[None, :, :, None]
    dmat = jnp.where(causal, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)                           # [B,t,1,H]
    w = jnp.exp(dmat - m)                                              # [B,t,s,H]
    qk = jnp.einsum("bthp,bshp->btsh", q.astype(jnp.float32), k.astype(jnp.float32))
    cmat = w * qk
    num = jnp.einsum("btsh,bshp->bthp", cmat, v.astype(jnp.float32))
    denom = jnp.maximum(jnp.abs(jnp.sum(cmat, axis=2)), jnp.exp(-m[:, :, 0, :]))
    y = (num / denom[..., None]).reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(gate)
    out = linear(params["down"], y)
    if return_state:
        # state after step S: decay of entry s is F_S - F_s + i_s
        d_end = F[:, -1:, :] - F + i_pre                               # [B, S, H]
        m_T = jnp.max(d_end, axis=1)                                   # [B, H]
        w = jnp.exp(d_end - m_T[:, None, :])                           # [B, S, H]
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        C = jnp.einsum("bsh,bshp,bshq->bhpq", w, kf, vf)
        n = jnp.einsum("bsh,bshp->bhp", w, kf)
        return out, {"C": C, "n": n, "m": m_T}
    return out


def mlstm_chunkwise(params, x, *, n_heads: int, chunk: int = 256,
                    return_state: bool = False):
    """Chunkwise-parallel mLSTM: quadratic only within a chunk, a lax.scan
    carries the (C, n, m) recurrent state across chunks. Matches
    mlstm_parallel (same stabilised math) while materialising
    O(S·chunk·H) instead of O(S²·H) — the S=4k train shape drops from a
    [B,4096,4096,H] decay tensor per layer to [B,256,256,H] per scan step.
    """
    B, S, dim = x.shape
    if S <= chunk:
        return mlstm_parallel(params, x, n_heads=n_heads,
                              return_state=return_state)
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    q, k, v, i_pre, f_pre, gate, d_inner, P = _mlstm_qkvif(params, x, n_heads)
    nc, L = S // chunk, chunk
    H = n_heads

    def rc(t):                                   # [B,S,...] -> [nc,B,L,...]
        return jnp.moveaxis(t.reshape(B, nc, L, *t.shape[2:]), 1, 0)

    qc, kc, vc = map(rc, (q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32)))
    ic, fc = rc(i_pre), rc(jax.nn.log_sigmoid(f_pre))

    def chunk_step(carry, inp):
        C_p, n_p, m_p = carry                    # [B,H,P,P], [B,H,P], [B,H]
        q_k, k_k, v_k, i_k, lf_k = inp           # [B,L,H,P] / [B,L,H]
        F = jnp.cumsum(lf_k, axis=1)             # [B,L,H] local decay prefix
        # intra-chunk decay D[t,s] = F_t - F_s + i_s  (s <= t)
        dloc = F[:, :, None, :] - F[:, None, :, :] + i_k[:, None, :, :]
        causal = jnp.tril(jnp.ones((L, L), dtype=bool))[None, :, :, None]
        dloc = jnp.where(causal, dloc, -jnp.inf)
        # carried-state decay at local t: m_p + F_t
        dst = m_p[:, None, :] + F                # [B,L,H]
        m_t = jnp.maximum(jnp.max(dloc, axis=2), dst)      # [B,L,H]
        w_loc = jnp.exp(dloc - m_t[:, :, None, :])          # [B,t,s,H]
        w_st = jnp.exp(dst - m_t)                           # [B,L,H]
        qk = jnp.einsum("bthp,bshp->btsh", q_k, k_k)
        cmat = w_loc * qk
        num = (jnp.einsum("btsh,bshp->bthp", cmat, v_k)
               + w_st[..., None] * jnp.einsum("bhpq,bthp->bthq", C_p, q_k))
        den = (jnp.sum(cmat, axis=2)
               + w_st * jnp.einsum("bhp,bthp->bth", n_p, q_k))
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        y_k = num / den[..., None]                          # [B,L,H,P]
        # state at chunk end: decay of local entry s is F_L - F_s + i_s
        d_end = F[:, -1:, :] - F + i_k                      # [B,L,H]
        m_end = jnp.maximum(m_p + F[:, -1], jnp.max(d_end, axis=1))  # [B,H]
        w_end = jnp.exp(d_end - m_end[:, None, :])          # [B,L,H]
        f_carry = jnp.exp(m_p + F[:, -1] - m_end)           # [B,H]
        C_n = (f_carry[..., None, None] * C_p
               + jnp.einsum("bsh,bshp,bshq->bhpq", w_end, k_k, v_k))
        n_n = f_carry[..., None] * n_p + jnp.einsum("bsh,bshp->bhp", w_end, k_k)
        return (C_n, n_n, m_end), y_k

    st0 = (jnp.zeros((B, H, P, P), jnp.float32),
           jnp.zeros((B, H, P), jnp.float32),
           jnp.full((B, H), -jnp.inf, jnp.float32))
    (C_f, n_f, m_f), ys = jax.lax.scan(
        chunk_step, st0, (qc, kc, vc, ic, fc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(gate)
    out = linear(params["down"], y)
    if return_state:
        return out, {"C": C_f, "n": n_f, "m": m_f}
    return out


def make_mlstm_state(batch: int, dim: int, n_heads: int, *, expand: int = 2,
                     dtype=jnp.float32):
    d_inner = expand * dim
    P = d_inner // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, P, P), dtype=jnp.float32),
        "n": jnp.zeros((batch, n_heads, P), dtype=jnp.float32),
        "m": jnp.full((batch, n_heads), -jnp.inf, dtype=jnp.float32),
    }


def mlstm_decode(params, x, state, *, n_heads: int):
    """One-token recurrent step. x [B, 1, dim]."""
    B, S, dim = x.shape
    assert S == 1
    q, k, v, i_pre, f_pre, gate, d_inner, P = _mlstm_qkvif(params, x, n_heads)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                                 # [B, H, P]
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]                             # [B, H]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    f_sc = jnp.exp(logf + state["m"] - m_new)
    i_sc = jnp.exp(i_pre - m_new)
    C = state["C"] * f_sc[..., None, None] + i_sc[..., None, None] * (
        k[..., :, None] * v[..., None, :])                              # [B,H,P,P]
    n = state["n"] * f_sc[..., None] + i_sc[..., None] * k
    num = jnp.einsum("bhpq,bhp->bhq", C, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.sum(n * q.astype(jnp.float32), axis=-1)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(gate)
    return linear(params["down"], y), {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------- sLSTM ----


def init_slstm(key, dim: int, n_heads: int, *, ff_factor: float = 4 / 3,
               dtype=jnp.float32):
    P = dim // n_heads
    ks = jax.random.split(key, 8)
    hid = int(ff_factor * dim)

    def gate_block(k):
        kw, kr = jax.random.split(k)
        return {
            "w": init_linear(kw, dim, dim, bias=True, dtype=dtype),
            # block-diagonal recurrence: per-head [P, P]
            "r": (jax.random.normal(kr, (n_heads, P, P), dtype=jnp.float32)
                  * (1.0 / P ** 0.5)).astype(dtype),
        }

    return {
        "z": gate_block(ks[0]), "i": gate_block(ks[1]),
        "f": gate_block(ks[2]), "o": gate_block(ks[3]),
        "norm": init_rmsnorm(dim, dtype=dtype),
        "ff_up": init_linear(ks[4], dim, hid, dtype=dtype),
        "ff_dn": init_linear(ks[5], hid, dim, dtype=dtype),
    }


def _slstm_gate(gp, wx_t, h_prev, n_heads: int):
    """wx_t [B, dim] (precomputed W·x), h_prev [B, H, P] -> pre-act [B, dim].

    The input projection is hoisted OUT of the time scan (one batched matmul
    over all S positions); only the block-diagonal recurrence R·h runs per
    step — the dense W would otherwise be re-read from HBM every timestep.
    """
    B = wx_t.shape[0]
    rec = jnp.einsum("bhp,hpq->bhq", h_prev.astype(jnp.float32),
                     gp["r"].astype(jnp.float32)).reshape(B, -1)
    return wx_t.astype(jnp.float32) + rec


def make_slstm_state(batch: int, dim: int, n_heads: int, *, dtype=jnp.float32):
    P = dim // n_heads
    sh = (batch, n_heads, P)
    # distinct buffers per leaf (decode donates the state)
    return {"c": jnp.zeros(sh, jnp.float32),
            "n": jnp.full(sh, 1e-6, jnp.float32),
            "h": jnp.zeros(sh, jnp.float32),
            "m": jnp.zeros(sh, jnp.float32)}


def _slstm_step(params, state, wx_t, n_heads: int):
    """wx_t: dict gate -> [B, dim] precomputed input projections."""
    B, dim = wx_t["z"].shape
    P = dim // n_heads
    h_prev = state["h"]
    zt = jnp.tanh(_slstm_gate(params["z"], wx_t["z"], h_prev, n_heads)).reshape(B, n_heads, P)
    it = _slstm_gate(params["i"], wx_t["i"], h_prev, n_heads).reshape(B, n_heads, P)
    ft = _slstm_gate(params["f"], wx_t["f"], h_prev, n_heads).reshape(B, n_heads, P)
    ot = jax.nn.sigmoid(_slstm_gate(params["o"], wx_t["o"], h_prev, n_heads)).reshape(B, n_heads, P)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + state["m"], it)
    i_sc = jnp.exp(it - m_new)
    f_sc = jnp.exp(logf + state["m"] - m_new)
    c = f_sc * state["c"] + i_sc * zt
    n = jnp.maximum(f_sc * state["n"] + i_sc, 1e-6)
    h = ot * (c / n)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_scan(params, x, *, n_heads: int, return_state: bool = False,
               chunk: int = 64, unroll: int = 8):
    """x [B, S, dim] -> y [B, S, dim] (sequential over S; input projections
    batched outside the scan, inner loop unrolled so the per-step
    block-diagonal einsums pipeline).

    Two-level scan: the outer scan stores one state per ``chunk`` while the
    rematerialised inner scan replays its chunk during the backward pass —
    trajectory storage drops S/chunk-fold vs a flat scan."""
    B, S, dim = x.shape
    # all four input projections for every position in one pass
    wx = {g: jnp.moveaxis(linear(params[g]["w"], x), 1, 0)   # [S, B, dim]
          for g in ("z", "i", "f", "o")}

    def step(state, wx_t):
        new = _slstm_step(params, state, wx_t, n_heads)
        return new, new["h"]

    state0 = make_slstm_state(B, dim, n_heads)
    if S > chunk and S % chunk == 0:
        wx_c = jax.tree.map(
            lambda t: t.reshape(S // chunk, chunk, *t.shape[1:]), wx)

        @jax.checkpoint
        def chunk_step(state, wx_k):
            return jax.lax.scan(step, state, wx_k, unroll=unroll)

        final, hs = jax.lax.scan(chunk_step, state0, wx_c)
        hs = hs.reshape(S, *hs.shape[2:])
    else:
        final, hs = jax.lax.scan(step, state0, wx, unroll=unroll)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, dim).astype(x.dtype)
    h = rmsnorm(params["norm"], h)
    out = linear(params["ff_dn"], jax.nn.gelu(linear(params["ff_up"], h)))
    if return_state:
        return out, final
    return out


def slstm_decode(params, x, state, *, n_heads: int):
    """One-token step. x [B, 1, dim]."""
    B, S, dim = x.shape
    assert S == 1
    wx_t = {g: linear(params[g]["w"], x[:, 0]) for g in ("z", "i", "f", "o")}
    new = _slstm_step(params, state, wx_t, n_heads)
    h = new["h"].reshape(B, 1, dim).astype(x.dtype)
    h = rmsnorm(params["norm"], h)
    y = linear(params["ff_dn"], jax.nn.gelu(linear(params["ff_up"], h)))
    return y, new
