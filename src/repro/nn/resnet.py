"""Residual MLP blocks — the paper's feature-extraction module (§IV-C:
"Raw data ... undergoes processing through a fully connected layer to reduce
dimensionality ... refined through several residual blocks")."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import init_linear, linear
from repro.nn.norms import init_layernorm, layernorm


def init_resblock(key, dim: int, *, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "ln": init_layernorm(dim, dtype=dtype),
        "fc1": init_linear(k1, dim, dim, bias=True, dtype=dtype),
        "fc2": init_linear(k2, dim, dim, bias=True, dtype=dtype),
    }


def resblock(params, x):
    h = layernorm(params["ln"], x)
    h = jax.nn.relu(linear(params["fc1"], h))
    h = linear(params["fc2"], h)
    return x + h


def init_res_mlp(key, in_dim: int, dim: int, n_blocks: int, *, dtype=jnp.float32):
    ks = jax.random.split(key, n_blocks + 1)
    return {
        "proj": init_linear(ks[0], in_dim, dim, bias=True, dtype=dtype),
        "blocks": [init_resblock(k, dim, dtype=dtype) for k in ks[1:]],
    }


def res_mlp(params, x):
    h = jax.nn.relu(linear(params["proj"], x))
    for bp in params["blocks"]:
        h = resblock(bp, h)
    return h
