"""Mamba2 (SSD) block — chunked state-space scan for training/prefill and a
single-step recurrence for decode.

Simplified-but-faithful SSD: per head h, state H_t in R^{P x N}:
    H_t = exp(dt_t * a_h) * H_{t-1} + dt_t * x_t B_t^T        (outer product)
    y_t = C_t^T H_t ... -> y_t[p] = sum_n H_t[p, n] C_t[n]
with x projected to heads of dim P, B/C of dim N shared across heads (MVA,
"multi-value attention" analog of GQA in Mamba2), scalar per-head decay a_h,
softplus-positive per-token-per-head dt, causal depthwise conv on (x, B, C),
gated output (z branch) and RMSNorm before out-projection.

The sequence scan is chunked: within a chunk the contribution is computed with
dense einsums (quadratic in chunk length — MXU-friendly), across chunks a
lax.scan carries the [P, N] state. This keeps peak memory at
O(chunk^2 + P*N) instead of O(T * P * N) for a naive associative scan.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.nn.linear import init_linear, linear
from repro.nn.norms import init_rmsnorm, rmsnorm

CONV_K = 4  # depthwise conv kernel width


def init_mamba2(key, dim: int, *, expand: int = 2, n_heads: int, d_state: int,
                dtype=jnp.float32):
    d_inner = expand * dim
    assert d_inner % n_heads == 0
    ks = jax.random.split(key, 6)
    conv_dim = d_inner + 2 * d_state
    return {
        # separate projections (z gate, x, [B;C], dt) so each output dim is
        # cleanly tensor-shardable — a fused in_proj would put the z/x/B/C/dt
        # split boundaries inside shards and force GSPMD gathers
        "in_z": init_linear(ks[3], dim, d_inner, dtype=dtype),
        "in_x": init_linear(ks[4], dim, d_inner, dtype=dtype),
        "in_bc": init_linear(ks[5], dim, 2 * d_state, dtype=dtype),
        "in_dt": init_linear(ks[0], dim, n_heads, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, conv_dim), dtype=jnp.float32)
                   * (1.0 / CONV_K ** 0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),  # A = -exp(a_log)
        "dt_bias": jnp.zeros((n_heads,), dtype=jnp.float32),
        "d_skip": jnp.ones((n_heads,), dtype=jnp.float32),
        "norm": init_rmsnorm(d_inner, dtype=dtype),
        "out_proj": init_linear(ks[2], d_inner, dim, dtype=dtype),
    }


def _split_proj(params, x, d_inner: int, d_state: int, n_heads: int):
    z = linear(params["in_z"], x)
    xs = linear(params["in_x"], x)
    B, C = jnp.split(linear(params["in_bc"], x), 2, axis=-1)
    dt = linear(params["in_dt"], x)
    return z, xs, B, C, dt


def _causal_conv(params, u, state=None):
    """u [B, S, conv_dim] -> same shape; depthwise causal conv width CONV_K.
    state [B, CONV_K-1, conv_dim] holds the trailing context for decode."""
    w = params["conv_w"].astype(jnp.float32)
    if state is None:
        pad = jnp.zeros((u.shape[0], CONV_K - 1, u.shape[2]), dtype=u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1).astype(jnp.float32)        # [B, S+K-1, D]
    out = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(CONV_K))
    out = jax.nn.silu(out + params["conv_b"].astype(jnp.float32))
    new_state = full[:, -(CONV_K - 1):].astype(u.dtype)
    return out.astype(u.dtype), new_state


def mamba2_scan(params, x, *, n_heads: int, d_state: int, expand: int = 2,
                chunk: int = 256, return_state: bool = False):
    """Full-sequence SSD. x [B, S, dim] -> y [B, S, dim]
    (or (y, state) with state usable by mamba2_decode when return_state)."""
    Bsz, S, dim = x.shape
    d_inner = expand * dim
    P = d_inner // n_heads
    z, xs, Bmat, Cmat, dt = _split_proj(params, x, d_inner, d_state, n_heads)
    conv_in = jnp.concatenate([xs, Bmat, Cmat], axis=-1)
    conv_out, _ = _causal_conv(params, conv_in)
    xs, Bmat, Cmat = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])     # [B, S, H]
    a = -jnp.exp(params["a_log"])                                        # [H]
    log_decay = dt * a                                                   # [B, S, H] (<=0)

    xh = xs.reshape(Bsz, S, n_heads, P).astype(jnp.float32)
    Bm = Bmat.astype(jnp.float32)                                        # [B, S, N]
    Cm = Cmat.astype(jnp.float32)                                        # [B, S, N]

    chunk = min(chunk, S)
    nchunks = S // chunk
    assert S % chunk == 0, f"seq {S} must be divisible by chunk {chunk}"

    def reshape_c(t):
        return t.reshape(Bsz, nchunks, chunk, *t.shape[2:])

    xh_c, Bm_c, Cm_c, ld_c, dt_c = map(reshape_c, (xh, Bm, Cm, log_decay, dt))
    # move chunk axis to front for scan: [nchunks, B, chunk, ...]
    xh_c, Bm_c, Cm_c, ld_c, dt_c = (jnp.moveaxis(t, 1, 0) for t in (xh_c, Bm_c, Cm_c, ld_c, dt_c))

    def chunk_step(H_prev, inp):
        xh_k, B_k, C_k, ld_k, dt_k = inp         # [B, L, H, P], [B, L, N], ...
        L = xh_k.shape[1]
        cum = jnp.cumsum(ld_k, axis=1)           # [B, L, H] cumulative log decay
        # intra-chunk: y_intra[t] = sum_{s<=t} exp(cum_t - cum_s) dt_s (C_t.B_s) x_s
        decay_ts = cum[:, :, None, :] - cum[:, None, :, :]               # [B, t, s, H]
        causal = jnp.tril(jnp.ones((L, L), dtype=bool))
        # mask the EXPONENT (not the exp output): for non-causal s>t the
        # exponent is large-positive -> exp overflows to inf, and
        # where(mask, inf, 0) still back-props NaN through the dead branch.
        safe_exp = jnp.where(causal[None, :, :, None], decay_ts, -jnp.inf)
        g = jnp.exp(safe_exp)                                            # [B,t,s,H]
        cb = jnp.einsum("btn,bsn->bts", C_k, B_k)                        # [B, t, s]
        w = g * cb[..., None] * dt_k[:, None, :, :]                      # [B,t,s,H]
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xh_k)
        # contribution of carried state: y_state[t] = exp(cum_t) C_t . H_prev
        y_state = jnp.einsum("bthn,bhpn->bthp",
                             jnp.exp(cum)[:, :, :, None] * C_k[:, :, None, :],
                             H_prev)
        # next state: H = exp(cum_L) H_prev + sum_s exp(cum_L - cum_s) dt_s x_s B_s^T
        tail = jnp.exp(cum[:, -1:, :] - cum)                             # [B, L, H]
        H_new = (jnp.exp(cum[:, -1])[:, :, None, None] * H_prev
                 + jnp.einsum("blh,blhp,bln->bhpn", tail * dt_k, xh_k, B_k))
        return H_new, y_intra + y_state

    H0 = jnp.zeros((Bsz, n_heads, P, d_state), dtype=jnp.float32)
    H_final, ys = jax.lax.scan(chunk_step, H0, (xh_c, Bm_c, Cm_c, ld_c, dt_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, n_heads, P)               # [B, S, H, P]
    y = y + params["d_skip"][None, None, :, None] * xh
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(z)
    out = linear(params["out_proj"], y)
    if return_state:
        conv_tail = conv_in[:, -(CONV_K - 1):]                           # pre-conv inputs
        return out, {"ssm": H_final, "conv": conv_tail}
    return out


def make_mamba_state(batch: int, dim: int, *, n_heads: int, d_state: int,
                     expand: int = 2, dtype=jnp.float32):
    d_inner = expand * dim
    P = d_inner // n_heads
    return {
        "ssm": jnp.zeros((batch, n_heads, P, d_state), dtype=jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, d_inner + 2 * d_state), dtype=dtype),
    }


def mamba2_decode(params, x, state, *, n_heads: int, d_state: int, expand: int = 2):
    """One-token step. x [B, 1, dim] -> (y [B, 1, dim], new_state)."""
    Bsz, S, dim = x.shape
    assert S == 1
    d_inner = expand * dim
    P = d_inner // n_heads
    z, xs, Bmat, Cmat, dt = _split_proj(params, x, d_inner, d_state, n_heads)
    conv_in = jnp.concatenate([xs, Bmat, Cmat], axis=-1)
    conv_out, conv_state = _causal_conv(params, conv_in, state["conv"])
    xs, Bmat, Cmat = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # [B, H]
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a)                                                 # [B, H]
    xh = xs[:, 0].reshape(Bsz, n_heads, P).astype(jnp.float32)
    Bm = Bmat[:, 0].astype(jnp.float32)                                     # [B, N]
    Cm = Cmat[:, 0].astype(jnp.float32)

    H = state["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bm)
    y = jnp.einsum("bhpn,bn->bhp", H, Cm) + params["d_skip"][None, :, None] * xh
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(z)
    return linear(params["out_proj"], y), {"ssm": H, "conv": conv_state}
