"""Plain LSTM (for the OPD workload predictor — paper §IV-A: 25-unit LSTM
followed by a one-unit dense layer)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import init_linear, linear


def init_lstm(key, in_dim: int, hidden: int, *, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wx": init_linear(k1, in_dim, 4 * hidden, bias=True, dtype=dtype),
        "wh": init_linear(k2, hidden, 4 * hidden, dtype=dtype),
    }


def lstm_scan(params, x):
    """x [B, T, in_dim] -> (h_seq [B, T, H], (h_T, c_T))."""
    B, T, _ = x.shape
    H = params["wh"]["w"].shape[0]

    def step(carry, x_t):
        h, c = carry
        z = linear(params["wx"], x_t) + linear(params["wh"], h)
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((B, H), dtype=x.dtype)
    (hT, cT), hs = jax.lax.scan(step, (h0, h0), jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(hs, 0, 1), (hT, cT)
