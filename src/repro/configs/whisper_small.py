"""whisper-small [arXiv:2212.04356] — encoder-decoder; conv/mel frontend is a
STUB (input_specs() provides encoder frame embeddings, enc_len=1500).

Decoder backbone: 12L d_model=768 12H (MHA, kv=12) d_ff=3072 vocab=51865,
learned positions, GELU MLP, LayerNorm."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072, vocab=51865,
    enc_len=1500, mlp_kind="gelu", norm="layernorm", rope_theta=None,
)
