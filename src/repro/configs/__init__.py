"""Assigned-architecture registry. Each module defines CONFIG (full-size) —
the exact published configuration — plus cites its source in the docstring.
"""
from repro.configs import (
    granite_moe_3b_a800m,
    granite_3_8b,
    llava_next_mistral_7b,
    deepseek_67b,
    starcoder2_3b,
    llama3_2_1b,
    whisper_small,
    zamba2_2_7b,
    xlstm_125m,
    llama4_maverick_400b_a17b,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        granite_moe_3b_a800m, granite_3_8b, llava_next_mistral_7b, deepseek_67b,
        starcoder2_3b, llama3_2_1b, whisper_small, zamba2_2_7b, xlstm_125m,
        llama4_maverick_400b_a17b,
    )
}


def get_arch(name: str):
    return ARCHS[name]
