"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B language backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000. Vision tower (anyres CLIP tiling) is a STUB — input_specs()
provides projected patch embeddings (n_patches=576 base tile)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=32000,
    n_patches=576,
)
