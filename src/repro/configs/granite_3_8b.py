"""granite-3-8b [hf:ibm-granite/granite-3.0-2b-base family, 8B point].

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, d_ff=12800, vocab=49155,
)
