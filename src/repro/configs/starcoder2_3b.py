"""starcoder2-3b [arXiv:2402.19173] — GQA kv=2, RoPE.

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152. StarCoder2 uses a
GELU MLP and layernorm."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv=2, d_ff=12288, vocab=49152,
    mlp_kind="gelu", norm="layernorm",
)
