"""zamba2-2.7b [arXiv:2411.15242] — hybrid: Mamba2 backbone with a shared
attention block applied periodically.

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64; shared
attention every 6 mamba layers (9 applications). The published model uses two
alternating shared blocks with LoRA-specialisation; we use one shared block
(noted in DESIGN.md)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv=32, d_ff=10240, vocab=32000,
    ssm_state=64, attn_every=6,
)
