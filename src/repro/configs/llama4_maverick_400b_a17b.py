"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Scout-17B-16E family].

48L d_model=5120 40H (GQA kv=8) d_ff=8192/expert vocab=202048, MoE 128
experts top-1, early-fusion multimodal (text path exercised; fusion embeds
via input_specs stub are not required for the language backbone)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=8192, vocab=202048,
    n_experts=128, top_k=1,
    # HF card: interleave_moe_layer_step=2 — MoE every other layer (the
    # alternating dense layers give the "400b" total; all-MoE would be 773B)
    moe_every=2,
)
