"""xlstm-125m [arXiv:2405.04517] — sLSTM + mLSTM blocks.

12L d_model=768 4H (kv=4) d_ff=0 (block-internal up-projections)
vocab=50304; every 4th layer is sLSTM (xLSTM[7:1]-style ratio)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    slstm_every=4, rope_theta=None,
)
